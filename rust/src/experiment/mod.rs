//! The experiment harness: everything needed to regenerate the paper's
//! evaluation (Figs. 4–7) plus the ablations DESIGN.md calls out, and the
//! cluster scenarios beyond it.
//!
//! A *run* is one condition (Minos or baseline) on one simulated day; a
//! *paired outcome* is both conditions on the identical platform draw
//! (same seed ⇒ same node pool and placement lottery, mirroring the paper
//! running both functions "at the same time"); a *week* is seven paired
//! outcomes with per-day variability regimes; a *cluster replay* drives a
//! multi-region trace against shared-node regions.
//!
//! Structure of the simulation stack (the kernel/world split):
//!
//! - `sim::kernel` owns the event-drive loop;
//! - [`world`] implements the paper's single-deployment semantics as a
//!   kernel `World` (and exports the cold-start gate both worlds share);
//! - [`cluster`] implements the multi-function shared-node region world
//!   and the multi-region replay engine;
//! - [`runner`] wires worlds into runs and fans independent runs out over
//!   threads (`util::parallel`), bit-identically at any thread count.

pub mod cluster;
pub mod config;
pub mod figures;
pub mod metrics;
pub mod report;
pub mod runner;
pub mod sweep;
pub(crate) mod world;

pub use cluster::{run_cluster, ClusterOutcome, DeploymentOutcome, RegionOutcome};
pub use config::ExperimentConfig;
pub use metrics::{
    FunctionBreakdown, InvocationRecord, MetricsMode, MetricsSink, RegionBreakdown,
    RunResult,
};
pub use runner::{
    run_paired, run_paired_threads, run_pretest, run_single, run_trace, run_trace_paired,
    run_trace_threads, run_week, run_week_threads, FunctionPairedOutcome,
    FunctionRunOutcome, PairedOutcome, TraceOutcome, TracePairedOutcome,
};

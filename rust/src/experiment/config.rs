//! Experiment configuration (paper §III-A, "Environment Configuration").

use std::sync::Arc;

use crate::coordinator::MinosConfig;
use crate::fault::{AdmissionConfig, FaultConfig, RetryConfig};
use crate::platform::billing::Billing;
use crate::platform::{ContentionCurve, PlatformConfig};
use crate::policy::{PolicySpec, RoutingSpec};
use crate::trace::ReplaySchedule;
use crate::workload::{FunctionSpec, VirtualUsers};

use super::metrics::MetricsMode;

/// Full configuration of one experiment day.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Day index (selects the day's variability regime; paper: 7 days).
    pub day: u32,
    /// Master seed; everything stochastic forks from this.
    pub seed: u64,
    /// Main-workload virtual users (paper: 10 VUs × 30 min, 1 s think).
    pub vus: VirtualUsers,
    /// Pre-test virtual users (paper: 10 VUs × 1 min).
    pub pretest_vus: VirtualUsers,
    /// Elysium percentile: threshold = this percentile of pre-test scores
    /// (paper: 60 ⇒ fastest 40 % pass).
    pub elysium_percentile: f64,
    /// During the pre-test, benchmark on warm invocations too (collects
    /// more samples from the same instance pool; the instances themselves
    /// are never terminated either way).
    pub pretest_bench_warm: bool,
    pub platform: PlatformConfig,
    pub function: FunctionSpec,
    /// Template for the Minos condition (threshold filled in by pre-test).
    pub minos: MinosConfig,
    pub billing: Billing,
    /// The selection policy (the treated condition's decision rule; the
    /// baseline arm always runs `NeverTerminate`). Per-function overrides
    /// in the trace registry take precedence. Replaces the old
    /// `online_update_every` special case — `PolicySpec::Online` is that
    /// collector ([`ExperimentConfig::with_online_threshold`]).
    pub policy: PolicySpec,
    /// Cross-region routing for cluster replays (admission-time; see
    /// `policy::routing`).
    pub routing: RoutingSpec,
    /// Intra-region sharding for cluster replays: split every region's
    /// node pool and deployments into this many independent
    /// sub-simulations (functions are assigned whole, by id rank —
    /// `policy::routing::assign_shards`). `1`, the default, is the
    /// unsharded engine, bit-identical to pre-sharding replays; larger
    /// counts decorrelate the sub-pools, so placement intentionally
    /// diverges from the unsharded run while staying bit-identical at
    /// any thread count. Ignored outside `run_cluster`.
    pub shards: u32,
    /// Open-loop mode: Poisson arrivals at this rate (requests/s) replace
    /// the closed-loop virtual users. This is the paper's actual
    /// deployment model (§IV "Workload Limitations": Minos requires an
    /// asynchronous queued workload); the closed loop is only the paper's
    /// load generator. `None` = closed loop.
    pub open_loop_rate_rps: Option<f64>,
    /// Trace-replay mode: deterministic arrivals at the scheduled times
    /// with per-arrival payload scales, replacing both the closed loop and
    /// the Poisson open loop. Shared (`Arc`) because multi-function runs
    /// clone the config per function. Takes precedence over
    /// `open_loop_rate_rps`.
    pub replay: Option<Arc<ReplaySchedule>>,
    /// How runs record their measurements: `Full` keeps every record
    /// (needed for the paper figures), `Streaming` folds them into
    /// O(1)-memory accumulators (the default for `minos replay`/`sweep`).
    /// Sinks only observe — the mode never changes a run's physics.
    pub metrics: MetricsMode,
    /// Observability: probe level, flight-recorder capacity, gauge
    /// cadence (`obs::ObsConfig::off()` by default). Probes only
    /// observe — an instrumented run's physics are bit-identical to an
    /// uninstrumented one.
    pub obs: crate::obs::ObsConfig,
    /// Failure injection: node churn (Weibull lifetimes), spawn failures,
    /// mid-flight invocation faults. Off by default — a faults-off run
    /// draws nothing from the fault RNG stream and is bit-identical to a
    /// build without the fault plane.
    pub fault: FaultConfig,
    /// Retry budget / backoff / per-invocation deadline governing every
    /// requeue path (Minos termination, crash, saturation, injected
    /// fault). The default is the historical unbounded-retry behaviour.
    pub retry: RetryConfig,
    /// Bounded admission for the coordinator queue (capacity + shedding).
    /// Default: unbounded, never sheds.
    pub admission: AdmissionConfig,
    /// Record per-attempt ground truth (realized factors, bench scores,
    /// phase durations, cold-start delays) for the offline optimality
    /// bounds (`bound/`). Off by default — recording draws no RNG and a
    /// recording-off run is bit-identical to the pre-recorder engine.
    pub record_attempts: bool,
}

impl ExperimentConfig {
    /// The paper's configuration for a given day.
    pub fn paper_day(day: u32) -> ExperimentConfig {
        ExperimentConfig {
            day,
            seed: 0x31A5 + day as u64, // per-day platform lottery
            vus: VirtualUsers::paper(),
            pretest_vus: VirtualUsers::pretest(),
            elysium_percentile: 60.0,
            pretest_bench_warm: true,
            platform: PlatformConfig::default(),
            function: FunctionSpec::weather(),
            minos: MinosConfig::paper_default(),
            billing: Billing::paper(),
            policy: PolicySpec::Fixed,
            routing: RoutingSpec::Trace,
            shards: 1,
            open_loop_rate_rps: None,
            replay: None,
            metrics: MetricsMode::Full,
            obs: crate::obs::ObsConfig::off(),
            fault: FaultConfig::default(),
            retry: RetryConfig::default(),
            admission: AdmissionConfig::default(),
            record_attempts: false,
        }
    }

    /// A scaled-down configuration for fast tests (2-minute horizon).
    pub fn smoke(day: u32, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_day(day);
        cfg.seed = seed;
        cfg.vus.horizon = crate::sim::SimTime::from_secs(120.0);
        cfg
    }

    /// The configuration a trace-calibrated replay runs under: the paper
    /// platform, streaming metrics (calibrated traces reach millions of
    /// invocations — full per-record sinks would hold them all), and the
    /// caller's seed.
    pub fn calibrated(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_day(0);
        cfg.seed = seed;
        cfg.metrics = MetricsMode::Streaming;
        cfg
    }

    /// Back-compat constructor for the old `online_update_every: Some(n)`
    /// field: the same configuration, expressed as a policy.
    pub fn with_online_threshold(mut self, update_every: u64) -> ExperimentConfig {
        self.policy = PolicySpec::Online { update_every };
        self
    }

    /// Couple node speed to load: instances slow their node down by
    /// `curve(resident / node_capacity)` (see `platform::contention`).
    /// Note the feedback loop this opens for the treated arm: terminating
    /// slow instances *changes* which nodes are slow, so online/epsilon
    /// policies calibrate against a moving target.
    pub fn with_contention(
        mut self,
        curve: ContentionCurve,
        node_capacity: u32,
    ) -> ExperimentConfig {
        self.platform.contention = curve;
        self.platform.node_capacity = node_capacity;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_day_matches_paper_parameters() {
        let c = ExperimentConfig::paper_day(0);
        assert_eq!(c.vus.n_vus, 10);
        assert_eq!(c.vus.horizon.as_secs(), 1_800.0);
        assert_eq!(c.pretest_vus.horizon.as_secs(), 60.0);
        assert_eq!(c.elysium_percentile, 60.0);
        assert_eq!(c.billing.tier().memory_mb, 256);
        assert!(c.minos.enabled);
    }

    #[test]
    fn days_differ_in_seed() {
        assert_ne!(
            ExperimentConfig::paper_day(0).seed,
            ExperimentConfig::paper_day(1).seed
        );
    }

    #[test]
    fn policy_defaults_to_the_paper_gate() {
        let c = ExperimentConfig::paper_day(0);
        assert_eq!(c.policy, PolicySpec::Fixed);
        assert_eq!(c.routing, RoutingSpec::Trace);
        assert_eq!(c.shards, 1, "paper config must stay unsharded");
        let online = c.with_online_threshold(25);
        assert_eq!(online.policy, PolicySpec::Online { update_every: 25 });
    }

    #[test]
    fn smoke_is_short() {
        assert_eq!(ExperimentConfig::smoke(0, 1).vus.horizon.as_secs(), 120.0);
    }

    #[test]
    fn calibrated_streams_metrics() {
        let c = ExperimentConfig::calibrated(0xCAFE);
        assert_eq!(c.seed, 0xCAFE);
        assert_eq!(c.metrics, MetricsMode::Streaming);
        assert!(c.minos.enabled);
    }

    #[test]
    fn robustness_knobs_default_off() {
        // The entire fault/retry/admission plane must be inert by default:
        // paper runs draw nothing from the fault stream and never shed.
        let c = ExperimentConfig::paper_day(0);
        assert!(c.fault.is_off(), "paper config must stay fault-free");
        assert!(c.retry.is_default(), "paper config must keep unbounded retries");
        assert!(c.admission.is_off(), "paper config must keep an unbounded queue");
        assert!(!c.record_attempts, "paper config must not record attempts");
        assert_eq!(c.retry.saturated_delay_ms, 100.0);
    }

    #[test]
    fn contention_defaults_off_and_builder_applies() {
        let c = ExperimentConfig::paper_day(0);
        assert!(c.platform.contention.is_off(), "paper config must stay contention-free");
        assert_eq!(c.platform.variability.drift_epoch_ms, 0.0);
        let curve = ContentionCurve::Power { strength: 0.5, exponent: 0.7 };
        let c = c.with_contention(curve, 4);
        assert_eq!(c.platform.contention, curve);
        assert_eq!(c.platform.node_capacity, 4);
    }
}

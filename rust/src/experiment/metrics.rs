//! Per-invocation records and run-level aggregates — the measurements the
//! paper reports: execution time, download duration, analysis duration,
//! benchmark duration/success, retry count (§III-A "Workload"), plus the
//! billing stream Fig. 6/7 are computed from.

use crate::sim::SimTime;

/// One successfully completed invocation.
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub inv_id: u64,
    pub vu: u32,
    pub submitted_at: SimTime,
    pub completed_at: SimTime,
    /// 1 + number of Minos terminations this invocation suffered.
    pub attempts: u32,
    /// The retry cap forced this invocation past the benchmark.
    pub forced: bool,
    /// The final (successful) attempt ran on a cold-started instance.
    pub cold: bool,
    /// Durations of the successful attempt, ms.
    pub prepare_ms: f64,
    pub analysis_ms: f64,
    /// Billed execution duration of the successful attempt, ms.
    pub exec_ms: f64,
    /// Benchmark duration on the successful attempt (None: warm/forced/baseline).
    pub bench_ms: Option<f64>,
    /// Real PJRT prediction, when the runner executes artifacts.
    pub prediction: Option<f32>,
}

impl InvocationRecord {
    /// End-to-end latency seen by the virtual user, ms.
    pub fn latency_ms(&self) -> f64 {
        self.completed_at.ms_since(self.submitted_at)
    }
}

/// One billed attempt (successful or terminated), for the cost stream.
#[derive(Debug, Clone, Copy)]
pub struct CostEvent {
    pub at: SimTime,
    pub usd: f64,
    /// Attempt ended in a Minos termination.
    pub terminated: bool,
}

/// Everything measured during one run (one condition, one day).
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    pub records: Vec<InvocationRecord>,
    pub cost_events: Vec<CostEvent>,
    /// Benchmark durations of every benchmarked cold start (incl. failed).
    pub bench_scores: Vec<f64>,
    pub terminations: u64,
    pub forced_passes: u64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub expired: u64,
    /// Instances recycled by the platform's lifetime cap.
    pub recycled: u64,
    /// The elysium threshold in force (∞ for baseline / pretest).
    pub threshold_ms: f64,
    /// Published online-threshold updates (when the §IV collector is on).
    pub online_pushes: u64,
}

impl RunResult {
    /// Number of successful requests (Fig. 5's metric).
    pub fn successful(&self) -> u64 {
        self.records.len() as u64
    }

    /// Total cost over all billed attempts, USD (Fig. 3 / Fig. 6 basis).
    pub fn total_cost_usd(&self) -> f64 {
        self.cost_events.iter().map(|e| e.usd).sum()
    }

    /// Average cost per million successful requests, USD (Fig. 6 metric).
    pub fn cost_per_million_usd(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.total_cost_usd() / self.records.len() as f64 * 1e6
    }

    /// Analysis durations, ms (Fig. 4 metric).
    pub fn analysis_durations(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.analysis_ms).collect()
    }

    pub fn prepare_durations(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.prepare_ms).collect()
    }

    pub fn exec_durations(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.exec_ms).collect()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.latency_ms()).collect()
    }

    /// Observed termination rate among benchmarked cold starts.
    pub fn termination_rate(&self) -> f64 {
        if self.bench_scores.is_empty() {
            return 0.0;
        }
        self.terminations as f64 / self.bench_scores.len() as f64
    }

    /// Running cost-per-success series on a fixed time grid (Fig. 7).
    /// Returns (t_seconds, cost_per_million) points.
    pub fn cost_series(&self, step_s: f64, horizon_s: f64) -> Vec<(f64, f64)> {
        let mut points = Vec::new();
        let mut cost_idx = 0usize;
        let mut rec_idx = 0usize;
        let mut cum_cost = 0.0f64;
        let mut cum_success = 0u64;
        // Events must be scanned in time order; records are completion-
        // ordered by construction, cost events likewise.
        let mut t = step_s;
        while t <= horizon_s + 1e-9 {
            let cutoff = SimTime::from_secs(t);
            while cost_idx < self.cost_events.len()
                && self.cost_events[cost_idx].at <= cutoff
            {
                cum_cost += self.cost_events[cost_idx].usd;
                cost_idx += 1;
            }
            while rec_idx < self.records.len()
                && self.records[rec_idx].completed_at <= cutoff
            {
                cum_success += 1;
                rec_idx += 1;
            }
            if cum_success > 0 {
                points.push((t, cum_cost / cum_success as f64 * 1e6));
            }
            t += step_s;
        }
        points
    }
}

/// Per-function aggregate of one trace-replay run — the row the
/// multi-function report prints (p50/p95 durations, cost, termination
/// rate, all per function id).
#[derive(Debug, Clone)]
pub struct FunctionBreakdown {
    pub function: u32,
    pub name: String,
    /// Arrivals the trace addressed to this function.
    pub arrivals: u64,
    pub successful: u64,
    /// End-to-end (submit → complete) latency percentiles, ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    /// Billed execution-duration percentiles, ms.
    pub p50_exec_ms: f64,
    pub p95_exec_ms: f64,
    pub terminations: u64,
    /// Terminations / benchmarked cold starts.
    pub termination_rate: f64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub total_cost_usd: f64,
    pub cost_per_million_usd: f64,
    /// Elysium threshold in force for this function.
    pub threshold_ms: f64,
}

impl FunctionBreakdown {
    /// Aggregate one function's run into its report row.
    pub fn from_run(function: u32, name: &str, arrivals: u64, r: &RunResult) -> FunctionBreakdown {
        let pct = |xs: &[f64], q: f64| -> f64 {
            if xs.is_empty() {
                0.0
            } else {
                crate::stats::percentile(xs, q)
            }
        };
        let lat = r.latencies();
        let exec = r.exec_durations();
        FunctionBreakdown {
            function,
            name: name.to_string(),
            arrivals,
            successful: r.successful(),
            p50_latency_ms: pct(&lat, 50.0),
            p95_latency_ms: pct(&lat, 95.0),
            p50_exec_ms: pct(&exec, 50.0),
            p95_exec_ms: pct(&exec, 95.0),
            terminations: r.terminations,
            termination_rate: r.termination_rate(),
            cold_starts: r.cold_starts,
            warm_hits: r.warm_hits,
            total_cost_usd: r.total_cost_usd(),
            cost_per_million_usd: r.cost_per_million_usd(),
            threshold_ms: r.threshold_ms,
        }
    }
}

/// Per-region aggregate of a cluster replay: the region's functions
/// pooled into one row (latency percentiles over every completed
/// invocation in the region, plus the shared platform counters the
/// region-level report prints).
#[derive(Debug, Clone)]
pub struct RegionBreakdown {
    pub region: u32,
    pub name: String,
    /// Number of functions deployed in this region.
    pub functions: usize,
    pub arrivals: u64,
    pub successful: u64,
    pub terminations: u64,
    /// Region-platform counters (shared across the region's functions).
    pub cold_starts: u64,
    pub warm_hits: u64,
    /// Pooled end-to-end latency percentiles, ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub total_cost_usd: f64,
    pub cost_per_million_usd: f64,
}

impl RegionBreakdown {
    /// Aggregate a region's per-function runs into its report row.
    /// `cold_starts`/`warm_hits` come from the region platform (they are
    /// shared across functions and not attributable per run here).
    pub fn from_runs(
        region: u32,
        name: &str,
        arrivals: u64,
        cold_starts: u64,
        warm_hits: u64,
        runs: &[&RunResult],
    ) -> RegionBreakdown {
        let mut latencies: Vec<f64> = Vec::new();
        let mut successful = 0u64;
        let mut terminations = 0u64;
        let mut total_cost_usd = 0.0f64;
        for r in runs {
            latencies.extend(r.latencies());
            successful += r.successful();
            terminations += r.terminations;
            total_cost_usd += r.total_cost_usd();
        }
        // One sort serves both percentile reads (regions pool up to the
        // whole trace's latencies).
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
        let pct = |q: f64| -> f64 {
            if latencies.is_empty() {
                0.0
            } else {
                crate::stats::descriptive::percentile_of_sorted(&latencies, q)
            }
        };
        RegionBreakdown {
            region,
            name: name.to_string(),
            functions: runs.len(),
            arrivals,
            successful,
            terminations,
            cold_starts,
            warm_hits,
            p50_latency_ms: pct(50.0),
            p95_latency_ms: pct(95.0),
            total_cost_usd,
            cost_per_million_usd: if successful == 0 {
                0.0
            } else {
                total_cost_usd / successful as f64 * 1e6
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(completed_s: f64, analysis: f64) -> InvocationRecord {
        InvocationRecord {
            inv_id: 1,
            vu: 0,
            submitted_at: SimTime::ZERO,
            completed_at: SimTime::from_secs(completed_s),
            attempts: 1,
            forced: false,
            cold: false,
            prepare_ms: 500.0,
            analysis_ms: analysis,
            exec_ms: 2_900.0,
            bench_ms: None,
            prediction: None,
        }
    }

    fn cost(at_s: f64, usd: f64) -> CostEvent {
        CostEvent { at: SimTime::from_secs(at_s), usd, terminated: false }
    }

    #[test]
    fn aggregates() {
        let r = RunResult {
            records: vec![rec(1.0, 2_000.0), rec(2.0, 2_200.0)],
            cost_events: vec![cost(1.0, 1e-5), cost(2.0, 1.2e-5)],
            ..Default::default()
        };
        assert_eq!(r.successful(), 2);
        assert!((r.total_cost_usd() - 2.2e-5).abs() < 1e-12);
        assert!((r.cost_per_million_usd() - 11.0).abs() < 1e-9);
        assert_eq!(r.analysis_durations(), vec![2_000.0, 2_200.0]);
    }

    #[test]
    fn latency_is_submit_to_complete() {
        let mut record = rec(3.0, 2_000.0);
        record.submitted_at = SimTime::from_secs(1.0);
        assert!((record.latency_ms() - 2_000.0).abs() < 1e-9);
    }

    #[test]
    fn cost_series_is_running_average() {
        let r = RunResult {
            records: vec![rec(10.0, 1.0), rec(30.0, 1.0)],
            cost_events: vec![cost(5.0, 10e-6), cost(25.0, 14e-6)],
            ..Default::default()
        };
        let series = r.cost_series(10.0, 40.0);
        // t=10: cost 10e-6 over 1 success = $10/M
        assert!((series[0].1 - 10.0).abs() < 1e-9);
        // t=30: cost 24e-6 over 2 successes = $12/M
        let at30 = series.iter().find(|(t, _)| (*t - 30.0).abs() < 1e-9).unwrap();
        assert!((at30.1 - 12.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run_is_safe() {
        let r = RunResult::default();
        assert_eq!(r.successful(), 0);
        assert_eq!(r.cost_per_million_usd(), 0.0);
        assert_eq!(r.termination_rate(), 0.0);
        assert!(r.cost_series(10.0, 100.0).is_empty());
    }

    #[test]
    fn function_breakdown_aggregates() {
        let mut records = Vec::new();
        for i in 0..100u64 {
            let mut x = rec(i as f64 + 2.0, 2_000.0);
            x.submitted_at = SimTime::from_secs(i as f64);
            x.exec_ms = 1_000.0 + i as f64 * 10.0; // 1000..1990
            records.push(x);
        }
        let r = RunResult {
            records,
            cost_events: vec![cost(1.0, 2e-5)],
            terminations: 5,
            bench_scores: vec![300.0; 20],
            cold_starts: 7,
            warm_hits: 93,
            threshold_ms: 410.0,
            ..Default::default()
        };
        let b = FunctionBreakdown::from_run(3, "weather-3", 100, &r);
        assert_eq!(b.function, 3);
        assert_eq!(b.successful, 100);
        assert_eq!(b.arrivals, 100);
        assert!((b.p50_exec_ms - 1_495.0).abs() < 1e-9);
        assert!((b.p95_exec_ms - 1_940.5).abs() < 1e-9);
        assert!((b.termination_rate - 0.25).abs() < 1e-12);
        assert!((b.total_cost_usd - 2e-5).abs() < 1e-18);
        assert!((b.cost_per_million_usd - 0.2).abs() < 1e-9);
        assert_eq!(b.threshold_ms, 410.0);
        assert!(b.p50_latency_ms > 0.0);
    }

    #[test]
    fn function_breakdown_of_empty_run() {
        let b = FunctionBreakdown::from_run(0, "idle", 0, &RunResult::default());
        assert_eq!(b.successful, 0);
        assert_eq!(b.p50_latency_ms, 0.0);
        assert_eq!(b.p95_exec_ms, 0.0);
        assert_eq!(b.termination_rate, 0.0);
    }

    #[test]
    fn region_breakdown_pools_functions() {
        let mut fast = RunResult::default();
        let mut slow = RunResult::default();
        for i in 0..10u64 {
            let mut a = rec(i as f64 + 1.0, 100.0);
            a.submitted_at = SimTime::from_secs(i as f64);
            fast.records.push(a);
            let mut b = rec(i as f64 + 3.0, 100.0);
            b.submitted_at = SimTime::from_secs(i as f64);
            slow.records.push(b);
        }
        fast.cost_events.push(cost(1.0, 1e-5));
        slow.cost_events.push(cost(1.0, 3e-5));
        slow.terminations = 2;
        let b = RegionBreakdown::from_runs(1, "iowa-1", 20, 4, 16, &[&fast, &slow]);
        assert_eq!(b.region, 1);
        assert_eq!(b.functions, 2);
        assert_eq!(b.arrivals, 20);
        assert_eq!(b.successful, 20);
        assert_eq!(b.terminations, 2);
        assert_eq!(b.cold_starts, 4);
        assert_eq!(b.warm_hits, 16);
        // Latencies pooled across both functions: half at 1 s, half 3 s.
        assert!((b.p50_latency_ms - 2_000.0).abs() < 1e-9);
        assert!(b.p95_latency_ms >= 3_000.0 - 1e-9);
        assert!((b.total_cost_usd - 4e-5).abs() < 1e-18);
        assert!((b.cost_per_million_usd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn region_breakdown_of_empty_region() {
        let b = RegionBreakdown::from_runs(0, "ghost", 0, 0, 0, &[]);
        assert_eq!(b.successful, 0);
        assert_eq!(b.cost_per_million_usd, 0.0);
        assert_eq!(b.p50_latency_ms, 0.0);
    }
}

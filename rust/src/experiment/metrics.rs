//! Per-invocation records and run-level aggregates — the measurements the
//! paper reports: execution time, download duration, analysis duration,
//! benchmark duration/success, retry count (§III-A "Workload"), plus the
//! billing stream Fig. 6/7 are computed from.
//!
//! §Perf — metrics sinks. A [`RunResult`] records through a
//! [`MetricsSink`] with two modes:
//!
//! - [`MetricsMode::Full`] keeps every [`InvocationRecord`] and
//!   [`CostEvent`] (today's behavior; required by the figure emitters and
//!   the bootstrap CIs) — memory grows linearly with the trace;
//! - [`MetricsMode::Streaming`] folds each invocation into O(1)-memory
//!   accumulators — Welford mean/variance, P² quantile markers, a
//!   fixed-width latency histogram, and windowed cost totals (all from
//!   `stats/`) — so million-invocation replays and sweeps run in constant
//!   resident memory per invocation.
//!
//! Sinks only *observe* a simulation; they never feed RNG draws or event
//! scheduling, so switching modes cannot change a run's physics (asserted
//! by the streaming-vs-full parity tests).

use crate::sim::SimTime;
use crate::stats::histogram::Histogram;
use crate::stats::{P2Quantile, Welford};

/// One successfully completed invocation.
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub inv_id: u64,
    pub vu: u32,
    pub submitted_at: SimTime,
    pub completed_at: SimTime,
    /// 1 + number of Minos terminations this invocation suffered.
    pub attempts: u32,
    /// The retry cap forced this invocation past the benchmark.
    pub forced: bool,
    /// The final (successful) attempt ran on a cold-started instance.
    pub cold: bool,
    /// Durations of the successful attempt, ms.
    pub prepare_ms: f64,
    pub analysis_ms: f64,
    /// Billed execution duration of the successful attempt, ms.
    pub exec_ms: f64,
    /// Benchmark duration on the successful attempt (None: warm/forced/baseline).
    pub bench_ms: Option<f64>,
    /// Real PJRT prediction, when the runner executes artifacts.
    pub prediction: Option<f32>,
}

impl InvocationRecord {
    /// End-to-end latency seen by the virtual user, ms.
    pub fn latency_ms(&self) -> f64 {
        self.completed_at.ms_since(self.submitted_at)
    }
}

/// One billed attempt (successful or terminated), for the cost stream.
#[derive(Debug, Clone, Copy)]
pub struct CostEvent {
    pub at: SimTime,
    pub usd: f64,
    /// Attempt ended in a Minos termination.
    pub terminated: bool,
}

/// How a run records its measurements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Keep every record and cost event (exact; memory grows with the
    /// trace). The figure emitters need this.
    #[default]
    Full,
    /// Fold measurements into O(1)-memory streaming accumulators.
    Streaming,
}

/// Streaming latency-histogram range: [0, 2 min) at 200 ms resolution.
const LAT_HIST_MAX_MS: f64 = 120_000.0;
const LAT_HIST_BUCKETS: usize = 600;
/// Streaming cost-window width, seconds of virtual time.
const COST_WINDOW_S: f64 = 60.0;

/// Windowed cost/success totals on a fixed virtual-time grid: the
/// streaming replacement for the full cost-event stream. Memory is
/// O(sim horizon / window), independent of the invocation count.
#[derive(Debug, Clone)]
pub struct CostWindows {
    width_s: f64,
    /// Per-window (billed USD, successful completions).
    windows: Vec<(f64, u64)>,
}

impl CostWindows {
    fn new(width_s: f64) -> CostWindows {
        CostWindows { width_s, windows: Vec::new() }
    }

    fn slot(&mut self, at: SimTime) -> &mut (f64, u64) {
        let idx = (at.as_secs() / self.width_s) as usize;
        if idx >= self.windows.len() {
            self.windows.resize(idx + 1, (0.0, 0));
        }
        &mut self.windows[idx]
    }

    fn record_cost(&mut self, at: SimTime, usd: f64) {
        self.slot(at).0 += usd;
    }

    fn record_success(&mut self, at: SimTime) {
        self.slot(at).1 += 1;
    }

    /// Window width, seconds.
    pub fn width_s(&self) -> f64 {
        self.width_s
    }

    /// Running cost-per-million series at window granularity:
    /// (window-end seconds, cumulative $ per 1M successes) for every
    /// window with at least one cumulative success.
    pub fn series_per_million(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(self.windows.len());
        let mut cost = 0.0f64;
        let mut successes = 0u64;
        for (i, &(c, n)) in self.windows.iter().enumerate() {
            cost += c;
            successes += n;
            if successes > 0 {
                out.push(((i + 1) as f64 * self.width_s, cost / successes as f64 * 1e6));
            }
        }
        out
    }
}

/// O(1)-memory accumulators for one run (the streaming sink's state).
#[derive(Debug, Clone)]
pub struct StreamingStats {
    completed: u64,
    bench_count: u64,
    cost_total_usd: f64,
    latency: Welford,
    prepare: Welford,
    analysis: Welford,
    exec: Welford,
    analysis_p50: P2Quantile,
    latency_p50: P2Quantile,
    latency_p95: P2Quantile,
    exec_p50: P2Quantile,
    exec_p95: P2Quantile,
    latency_hist: Histogram,
    cost_windows: CostWindows,
}

impl StreamingStats {
    fn new() -> StreamingStats {
        StreamingStats {
            completed: 0,
            bench_count: 0,
            cost_total_usd: 0.0,
            latency: Welford::new(),
            prepare: Welford::new(),
            analysis: Welford::new(),
            exec: Welford::new(),
            analysis_p50: P2Quantile::new(0.5),
            latency_p50: P2Quantile::new(0.5),
            latency_p95: P2Quantile::new(0.95),
            exec_p50: P2Quantile::new(0.5),
            exec_p95: P2Quantile::new(0.95),
            latency_hist: Histogram::new(0.0, LAT_HIST_MAX_MS, LAT_HIST_BUCKETS),
            cost_windows: CostWindows::new(COST_WINDOW_S),
        }
    }

    fn record(&mut self, rec: &InvocationRecord) {
        self.completed += 1;
        let lat = rec.latency_ms();
        self.latency.push(lat);
        self.latency_p50.push(lat);
        self.latency_p95.push(lat);
        self.latency_hist.record(lat);
        self.prepare.push(rec.prepare_ms);
        self.analysis.push(rec.analysis_ms);
        self.analysis_p50.push(rec.analysis_ms);
        self.exec.push(rec.exec_ms);
        self.exec_p50.push(rec.exec_ms);
        self.exec_p95.push(rec.exec_ms);
        self.cost_windows.record_success(rec.completed_at);
    }
}

/// Where a run's measurements go: the full record vectors, or the
/// streaming accumulators.
#[derive(Debug, Clone)]
pub enum MetricsSink {
    Full {
        records: Vec<InvocationRecord>,
        cost_events: Vec<CostEvent>,
        /// Benchmark durations of every benchmarked cold start (incl. failed).
        bench_scores: Vec<f64>,
    },
    Streaming(Box<StreamingStats>),
}

impl MetricsSink {
    fn new(mode: MetricsMode) -> MetricsSink {
        match mode {
            MetricsMode::Full => MetricsSink::Full {
                records: Vec::new(),
                cost_events: Vec::new(),
                bench_scores: Vec::new(),
            },
            MetricsMode::Streaming => MetricsSink::Streaming(Box::new(StreamingStats::new())),
        }
    }
}

impl Default for MetricsSink {
    fn default() -> MetricsSink {
        MetricsSink::new(MetricsMode::Full)
    }
}

/// Everything measured during one run (one condition, one day).
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    /// Per-invocation measurements (full vectors or streaming folds).
    pub sink: MetricsSink,
    pub terminations: u64,
    pub forced_passes: u64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub expired: u64,
    /// Instances recycled by the platform's lifetime cap.
    pub recycled: u64,
    /// The elysium threshold in force (∞ for baseline / pretest).
    pub threshold_ms: f64,
    /// Published online-threshold updates (when the §IV collector is on).
    pub online_pushes: u64,
    /// Terminal failures: the retry budget ran out.
    pub failed_exhausted: u64,
    /// Terminal failures: the per-invocation deadline passed.
    pub failed_deadline: u64,
    /// Requests shed by bounded admission (rejects + evictions).
    pub shed: u64,
    /// In-flight attempts killed by injected invocation faults.
    pub inflight_faults: u64,
    /// Cold starts killed by injected spawn failures.
    pub spawn_failed: u64,
    /// Fault-injected node deaths.
    pub node_faults: u64,
    /// High-water mark of the invocation queue depth.
    pub queue_peak_depth: u64,
    /// Histogram of attempts-per-completed-request: bucket `i` counts
    /// requests that took `i + 1` attempts; the last bucket is `8+`.
    pub retry_histogram: [u64; 8],
    /// Flight-recorder capture (None unless the run was instrumented —
    /// see `obs`). Observation only: never feeds back into physics.
    pub obs: Option<Box<crate::obs::ObsData>>,
    /// Attempt log for the offline optimality bounds (None unless
    /// `cfg.record_attempts`). Observation only, like `obs`.
    pub attempts: Option<Box<crate::bound::AttemptLog>>,
}

impl RunResult {
    /// A result recording through the given sink mode.
    pub fn new(mode: MetricsMode) -> RunResult {
        RunResult { sink: MetricsSink::new(mode), ..Default::default() }
    }

    pub fn mode(&self) -> MetricsMode {
        match self.sink {
            MetricsSink::Full { .. } => MetricsMode::Full,
            MetricsSink::Streaming(_) => MetricsMode::Streaming,
        }
    }

    /// Record one successful completion.
    pub fn record_invocation(&mut self, rec: InvocationRecord) {
        self.note_attempts(rec.attempts);
        match &mut self.sink {
            MetricsSink::Full { records, .. } => records.push(rec),
            MetricsSink::Streaming(s) => s.record(&rec),
        }
    }

    /// Fold one completed request's attempt count into the retry histogram.
    fn note_attempts(&mut self, attempts: u32) {
        let bucket = (attempts.max(1) as usize - 1).min(self.retry_histogram.len() - 1);
        self.retry_histogram[bucket] += 1;
    }

    /// Terminal failures of both kinds (goodput denominator companion).
    pub fn failed(&self) -> u64 {
        self.failed_exhausted + self.failed_deadline
    }

    /// Fraction of adjudicated requests (completed + failed + shed) that
    /// failed or were shed. 0 for an all-success run.
    pub fn failure_rate(&self) -> f64 {
        let bad = self.failed() + self.shed;
        let total = self.successful() + bad;
        if total == 0 {
            0.0
        } else {
            bad as f64 / total as f64
        }
    }

    /// Record one billed attempt.
    pub fn record_cost(&mut self, ev: CostEvent) {
        match &mut self.sink {
            MetricsSink::Full { cost_events, .. } => cost_events.push(ev),
            MetricsSink::Streaming(s) => {
                s.cost_total_usd += ev.usd;
                s.cost_windows.record_cost(ev.at, ev.usd);
            }
        }
    }

    /// Record one benchmark score (every benchmarked cold start).
    pub fn record_bench(&mut self, score_ms: f64) {
        match &mut self.sink {
            MetricsSink::Full { bench_scores, .. } => bench_scores.push(score_ms),
            MetricsSink::Streaming(s) => s.bench_count += 1,
        }
    }

    /// The full per-invocation records (empty in streaming mode).
    pub fn records(&self) -> &[InvocationRecord] {
        match &self.sink {
            MetricsSink::Full { records, .. } => records,
            MetricsSink::Streaming(_) => &[],
        }
    }

    /// The full billed-attempt stream (empty in streaming mode).
    pub fn cost_events(&self) -> &[CostEvent] {
        match &self.sink {
            MetricsSink::Full { cost_events, .. } => cost_events,
            MetricsSink::Streaming(_) => &[],
        }
    }

    /// The raw benchmark scores (empty in streaming mode — use
    /// [`RunResult::bench_count`] there).
    pub fn bench_scores(&self) -> &[f64] {
        match &self.sink {
            MetricsSink::Full { bench_scores, .. } => bench_scores,
            MetricsSink::Streaming(_) => &[],
        }
    }

    /// Number of benchmarked cold starts (exact in both modes).
    pub fn bench_count(&self) -> u64 {
        match &self.sink {
            MetricsSink::Full { bench_scores, .. } => bench_scores.len() as u64,
            MetricsSink::Streaming(s) => s.bench_count,
        }
    }

    /// Number of successful requests (Fig. 5's metric; exact in both modes).
    pub fn successful(&self) -> u64 {
        match &self.sink {
            MetricsSink::Full { records, .. } => records.len() as u64,
            MetricsSink::Streaming(s) => s.completed,
        }
    }

    /// Total cost over all billed attempts, USD (Fig. 3 / Fig. 6 basis;
    /// exact in both modes).
    pub fn total_cost_usd(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { cost_events, .. } => cost_events.iter().map(|e| e.usd).sum(),
            MetricsSink::Streaming(s) => s.cost_total_usd,
        }
    }

    /// Average cost per million successful requests, USD (Fig. 6 metric).
    pub fn cost_per_million_usd(&self) -> f64 {
        let n = self.successful();
        if n == 0 {
            return 0.0;
        }
        self.total_cost_usd() / n as f64 * 1e6
    }

    /// Analysis durations, ms (Fig. 4 metric; full mode only — empty when
    /// streaming).
    pub fn analysis_durations(&self) -> Vec<f64> {
        self.records().iter().map(|r| r.analysis_ms).collect()
    }

    pub fn prepare_durations(&self) -> Vec<f64> {
        self.records().iter().map(|r| r.prepare_ms).collect()
    }

    pub fn exec_durations(&self) -> Vec<f64> {
        self.records().iter().map(|r| r.exec_ms).collect()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.records().iter().map(|r| r.latency_ms()).collect()
    }

    /// Mean analysis duration, ms — exact in full mode, Welford in
    /// streaming mode (same value up to floating-point association).
    pub fn analysis_mean_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => crate::stats::mean(&self.analysis_durations()),
            MetricsSink::Streaming(s) => s.analysis.mean(),
        }
    }

    /// Mean end-to-end latency, ms (exact / Welford by mode).
    pub fn latency_mean_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => crate::stats::mean(&self.latencies()),
            MetricsSink::Streaming(s) => s.latency.mean(),
        }
    }

    /// Mean prepare (download) duration, ms (exact / Welford by mode).
    pub fn prepare_mean_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => crate::stats::mean(&self.prepare_durations()),
            MetricsSink::Streaming(s) => s.prepare.mean(),
        }
    }

    /// Median analysis duration, ms — exact in full mode, P² estimate in
    /// streaming mode. 0.0 for an empty run.
    pub fn analysis_median_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { records, .. } => {
                if records.is_empty() {
                    0.0
                } else {
                    crate::stats::median(&self.analysis_durations())
                }
            }
            MetricsSink::Streaming(s) => s.analysis_p50.estimate(),
        }
    }

    fn full_pct(xs: &[f64], q: f64) -> f64 {
        if xs.is_empty() {
            0.0
        } else {
            crate::stats::percentile(xs, q)
        }
    }

    /// End-to-end latency p50, ms (exact / P² by mode).
    pub fn latency_p50_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => Self::full_pct(&self.latencies(), 50.0),
            MetricsSink::Streaming(s) => s.latency_p50.estimate(),
        }
    }

    /// End-to-end latency p95, ms (exact / P² by mode).
    pub fn latency_p95_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => Self::full_pct(&self.latencies(), 95.0),
            MetricsSink::Streaming(s) => s.latency_p95.estimate(),
        }
    }

    /// Billed execution-duration p50, ms (exact / P² by mode).
    pub fn exec_p50_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => Self::full_pct(&self.exec_durations(), 50.0),
            MetricsSink::Streaming(s) => s.exec_p50.estimate(),
        }
    }

    /// Billed execution-duration p95, ms (exact / P² by mode).
    pub fn exec_p95_ms(&self) -> f64 {
        match &self.sink {
            MetricsSink::Full { .. } => Self::full_pct(&self.exec_durations(), 95.0),
            MetricsSink::Streaming(s) => s.exec_p95.estimate(),
        }
    }

    /// The streaming latency histogram, when in streaming mode (used to
    /// pool latency distributions across runs without records).
    pub fn latency_histogram(&self) -> Option<&Histogram> {
        match &self.sink {
            MetricsSink::Full { .. } => None,
            MetricsSink::Streaming(s) => Some(&s.latency_hist),
        }
    }

    /// Observed termination rate among benchmarked cold starts.
    pub fn termination_rate(&self) -> f64 {
        let benched = self.bench_count();
        if benched == 0 {
            return 0.0;
        }
        self.terminations as f64 / benched as f64
    }

    /// Running cost-per-success series (Fig. 7). Returns
    /// (t_seconds, cost_per_million) points. Full mode: exact on the
    /// requested `step_s` grid. Streaming mode: at the sink's fixed
    /// cost-window granularity (`step_s` is ignored), clipped to the
    /// horizon.
    pub fn cost_series(&self, step_s: f64, horizon_s: f64) -> Vec<(f64, f64)> {
        match &self.sink {
            MetricsSink::Full { records, cost_events, .. } => {
                let mut points = Vec::new();
                let mut cost_idx = 0usize;
                let mut rec_idx = 0usize;
                let mut cum_cost = 0.0f64;
                let mut cum_success = 0u64;
                // Events must be scanned in time order; records are
                // completion-ordered by construction, cost events likewise.
                let mut t = step_s;
                while t <= horizon_s + 1e-9 {
                    let cutoff = SimTime::from_secs(t);
                    while cost_idx < cost_events.len() && cost_events[cost_idx].at <= cutoff {
                        cum_cost += cost_events[cost_idx].usd;
                        cost_idx += 1;
                    }
                    while rec_idx < records.len() && records[rec_idx].completed_at <= cutoff {
                        cum_success += 1;
                        rec_idx += 1;
                    }
                    if cum_success > 0 {
                        points.push((t, cum_cost / cum_success as f64 * 1e6));
                    }
                    t += step_s;
                }
                points
            }
            MetricsSink::Streaming(s) => {
                let width = s.cost_windows.width_s();
                let mut points = s.cost_windows.series_per_million();
                // Keep every window that *starts* before the horizon and
                // clamp its stamp to the horizon, so a partial final
                // window still reports the data recorded inside it.
                points.retain(|&(t, _)| t - width < horizon_s - 1e-9);
                for p in &mut points {
                    p.0 = p.0.min(horizon_s);
                }
                points
            }
        }
    }
}

/// Per-function aggregate of one trace-replay run — the row the
/// multi-function report prints (p50/p95 durations, cost, termination
/// rate, all per function id). Works over both sink modes: exact
/// percentiles from full records, P² estimates from streaming runs.
#[derive(Debug, Clone)]
pub struct FunctionBreakdown {
    pub function: u32,
    pub name: String,
    /// Arrivals the trace addressed to this function.
    pub arrivals: u64,
    pub successful: u64,
    /// End-to-end (submit → complete) latency percentiles, ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    /// Billed execution-duration percentiles, ms.
    pub p50_exec_ms: f64,
    pub p95_exec_ms: f64,
    pub terminations: u64,
    /// Terminations / benchmarked cold starts.
    pub termination_rate: f64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub total_cost_usd: f64,
    pub cost_per_million_usd: f64,
    /// Elysium threshold in force for this function.
    pub threshold_ms: f64,
}

impl FunctionBreakdown {
    /// Aggregate one function's run into its report row.
    pub fn from_run(function: u32, name: &str, arrivals: u64, r: &RunResult) -> FunctionBreakdown {
        FunctionBreakdown {
            function,
            name: name.to_string(),
            arrivals,
            successful: r.successful(),
            p50_latency_ms: r.latency_p50_ms(),
            p95_latency_ms: r.latency_p95_ms(),
            p50_exec_ms: r.exec_p50_ms(),
            p95_exec_ms: r.exec_p95_ms(),
            terminations: r.terminations,
            termination_rate: r.termination_rate(),
            cold_starts: r.cold_starts,
            warm_hits: r.warm_hits,
            total_cost_usd: r.total_cost_usd(),
            cost_per_million_usd: r.cost_per_million_usd(),
            threshold_ms: r.threshold_ms,
        }
    }
}

/// Billed-execution p50 at or above this is a "long" function, ms.
pub const LONG_EXEC_MS: f64 = 1_000.0;
/// Warm-start share at or above this is "hot" (almost every start warm).
pub const HOT_WARM_SHARE: f64 = 0.9;
/// Warm-start share at or above this (below hot) is "warm"; below it the
/// function is cold-dominant.
pub const WARM_WARM_SHARE: f64 = 0.5;

/// Start temperature of a function's run: what share of its instance
/// starts were warm hits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TempClass {
    Hot,
    Warm,
    Cold,
}

/// Duration class by p50 billed execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DurClass {
    Short,
    Long,
}

/// SeBS-style workload class of one function's run: start temperature ×
/// duration. This is the axis the paper's claim is conditioned on — the
/// gate only fires on cold starts, so cold-dominant long functions are
/// where Minos has both opportunity and payoff.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadClass {
    pub temp: TempClass,
    pub dur: DurClass,
}

impl WorkloadClass {
    /// Classify one function's report row.
    pub fn of(b: &FunctionBreakdown) -> WorkloadClass {
        let starts = b.cold_starts + b.warm_hits;
        let warm_share = if starts == 0 { 0.0 } else { b.warm_hits as f64 / starts as f64 };
        let temp = if warm_share >= HOT_WARM_SHARE {
            TempClass::Hot
        } else if warm_share >= WARM_WARM_SHARE {
            TempClass::Warm
        } else {
            TempClass::Cold
        };
        let dur = if b.p50_exec_ms >= LONG_EXEC_MS { DurClass::Long } else { DurClass::Short };
        WorkloadClass { temp, dur }
    }

    pub fn label(&self) -> &'static str {
        match (self.temp, self.dur) {
            (TempClass::Hot, DurClass::Short) => "hot/short",
            (TempClass::Hot, DurClass::Long) => "hot/long",
            (TempClass::Warm, DurClass::Short) => "warm/short",
            (TempClass::Warm, DurClass::Long) => "warm/long",
            (TempClass::Cold, DurClass::Short) => "cold/short",
            (TempClass::Cold, DurClass::Long) => "cold/long",
        }
    }

    /// Every class, in fixed report order.
    pub fn all() -> [WorkloadClass; 6] {
        [TempClass::Hot, TempClass::Warm, TempClass::Cold]
            .into_iter()
            .flat_map(|temp| {
                [DurClass::Short, DurClass::Long]
                    .into_iter()
                    .map(move |dur| WorkloadClass { temp, dur })
            })
            .collect::<Vec<_>>()
            .try_into()
            .expect("3 x 2 classes")
    }
}

/// One row of the workload-class rollup: every function of the class
/// pooled.
#[derive(Debug, Clone)]
pub struct ClassBreakdown {
    pub class: WorkloadClass,
    pub functions: usize,
    pub arrivals: u64,
    pub successful: u64,
    pub terminations: u64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub total_cost_usd: f64,
    pub cost_per_million_usd: f64,
    /// Success-weighted mean of the members' p50 billed execution, ms.
    pub mean_p50_exec_ms: f64,
}

/// Roll per-function rows up into workload classes (fixed class order,
/// empty classes omitted) — deterministic for a deterministic input.
pub fn class_rollup(rows: &[FunctionBreakdown]) -> Vec<ClassBreakdown> {
    WorkloadClass::all()
        .into_iter()
        .filter_map(|class| {
            let members: Vec<&FunctionBreakdown> =
                rows.iter().filter(|b| WorkloadClass::of(b) == class).collect();
            if members.is_empty() {
                return None;
            }
            let mut c = ClassBreakdown {
                class,
                functions: members.len(),
                arrivals: 0,
                successful: 0,
                terminations: 0,
                cold_starts: 0,
                warm_hits: 0,
                total_cost_usd: 0.0,
                cost_per_million_usd: 0.0,
                mean_p50_exec_ms: 0.0,
            };
            let mut exec_weighted = 0.0f64;
            for b in &members {
                c.arrivals += b.arrivals;
                c.successful += b.successful;
                c.terminations += b.terminations;
                c.cold_starts += b.cold_starts;
                c.warm_hits += b.warm_hits;
                c.total_cost_usd += b.total_cost_usd;
                exec_weighted += b.p50_exec_ms * b.successful as f64;
            }
            if c.successful > 0 {
                c.cost_per_million_usd = c.total_cost_usd / c.successful as f64 * 1e6;
                c.mean_p50_exec_ms = exec_weighted / c.successful as f64;
            }
            Some(c)
        })
        .collect()
}

/// Per-region aggregate of a cluster replay: the region's functions
/// pooled into one row (latency percentiles over every completed
/// invocation in the region, plus the shared platform counters the
/// region-level report prints).
#[derive(Debug, Clone)]
pub struct RegionBreakdown {
    pub region: u32,
    pub name: String,
    /// Number of functions deployed in this region.
    pub functions: usize,
    pub arrivals: u64,
    pub successful: u64,
    pub terminations: u64,
    /// Region-platform counters (shared across the region's functions).
    pub cold_starts: u64,
    pub warm_hits: u64,
    /// Pooled end-to-end latency percentiles, ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub total_cost_usd: f64,
    pub cost_per_million_usd: f64,
}

impl RegionBreakdown {
    /// Aggregate a region's per-function runs into its report row.
    /// `cold_starts`/`warm_hits` come from the region platform (they are
    /// shared across functions and not attributable per run here).
    ///
    /// Full-mode runs pool exact latencies; streaming runs pool their
    /// fixed-width latency histograms (identical bounds merge exactly)
    /// and read the percentiles off the merged histogram.
    pub fn from_runs(
        region: u32,
        name: &str,
        arrivals: u64,
        cold_starts: u64,
        warm_hits: u64,
        runs: &[&RunResult],
    ) -> RegionBreakdown {
        let mut successful = 0u64;
        let mut terminations = 0u64;
        let mut total_cost_usd = 0.0f64;
        for r in runs {
            successful += r.successful();
            terminations += r.terminations;
            total_cost_usd += r.total_cost_usd();
        }
        let streaming = runs.iter().any(|r| r.mode() == MetricsMode::Streaming);
        let (p50, p95) = if streaming {
            let mut pooled: Option<Histogram> = None;
            for r in runs {
                let h = r
                    .latency_histogram()
                    .expect("regions must not mix full and streaming runs");
                match &mut pooled {
                    None => pooled = Some(h.clone()),
                    Some(p) => p.merge(h),
                }
            }
            match pooled {
                Some(h) if h.count() > 0 => (h.quantile(0.5), h.quantile(0.95)),
                _ => (0.0, 0.0),
            }
        } else {
            let mut latencies: Vec<f64> = Vec::new();
            for r in runs {
                latencies.extend(r.latencies());
            }
            // One sort serves both percentile reads (regions pool up to
            // the whole trace's latencies).
            latencies.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
            let pct = |q: f64| -> f64 {
                if latencies.is_empty() {
                    0.0
                } else {
                    crate::stats::descriptive::percentile_of_sorted(&latencies, q)
                }
            };
            (pct(50.0), pct(95.0))
        };
        RegionBreakdown {
            region,
            name: name.to_string(),
            functions: runs.len(),
            arrivals,
            successful,
            terminations,
            cold_starts,
            warm_hits,
            p50_latency_ms: p50,
            p95_latency_ms: p95,
            total_cost_usd,
            cost_per_million_usd: if successful == 0 {
                0.0
            } else {
                total_cost_usd / successful as f64 * 1e6
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(completed_s: f64, analysis: f64) -> InvocationRecord {
        InvocationRecord {
            inv_id: 1,
            vu: 0,
            submitted_at: SimTime::ZERO,
            completed_at: SimTime::from_secs(completed_s),
            attempts: 1,
            forced: false,
            cold: false,
            prepare_ms: 500.0,
            analysis_ms: analysis,
            exec_ms: 2_900.0,
            bench_ms: None,
            prediction: None,
        }
    }

    fn cost(at_s: f64, usd: f64) -> CostEvent {
        CostEvent { at: SimTime::from_secs(at_s), usd, terminated: false }
    }

    #[test]
    fn retry_histogram_and_failure_rate() {
        let mut r = RunResult::new(MetricsMode::Full);
        for attempts in [1, 1, 2, 3, 99] {
            let mut rc = rec(1.0, 100.0);
            rc.attempts = attempts;
            r.record_invocation(rc);
        }
        assert_eq!(r.retry_histogram[0], 2, "one-attempt requests");
        assert_eq!(r.retry_histogram[1], 1);
        assert_eq!(r.retry_histogram[2], 1);
        assert_eq!(r.retry_histogram[7], 1, "8+ attempts land in the last bucket");
        assert_eq!(r.failure_rate(), 0.0);
        r.failed_exhausted = 2;
        r.failed_deadline = 1;
        r.shed = 2;
        assert_eq!(r.failed(), 3);
        // 5 completed + 5 failed/shed.
        assert!((r.failure_rate() - 0.5).abs() < 1e-12);
    }

    fn full_with(records: Vec<InvocationRecord>, costs: Vec<CostEvent>) -> RunResult {
        let mut r = RunResult::new(MetricsMode::Full);
        for rec in records {
            r.record_invocation(rec);
        }
        for c in costs {
            r.record_cost(c);
        }
        r
    }

    #[test]
    fn aggregates() {
        let r = full_with(
            vec![rec(1.0, 2_000.0), rec(2.0, 2_200.0)],
            vec![cost(1.0, 1e-5), cost(2.0, 1.2e-5)],
        );
        assert_eq!(r.successful(), 2);
        assert!((r.total_cost_usd() - 2.2e-5).abs() < 1e-12);
        assert!((r.cost_per_million_usd() - 11.0).abs() < 1e-9);
        assert_eq!(r.analysis_durations(), vec![2_000.0, 2_200.0]);
    }

    #[test]
    fn latency_is_submit_to_complete() {
        let mut record = rec(3.0, 2_000.0);
        record.submitted_at = SimTime::from_secs(1.0);
        assert!((record.latency_ms() - 2_000.0).abs() < 1e-9);
    }

    #[test]
    fn cost_series_is_running_average() {
        let r = full_with(
            vec![rec(10.0, 1.0), rec(30.0, 1.0)],
            vec![cost(5.0, 10e-6), cost(25.0, 14e-6)],
        );
        let series = r.cost_series(10.0, 40.0);
        // t=10: cost 10e-6 over 1 success = $10/M
        assert!((series[0].1 - 10.0).abs() < 1e-9);
        // t=30: cost 24e-6 over 2 successes = $12/M
        let at30 = series.iter().find(|(t, _)| (*t - 30.0).abs() < 1e-9).unwrap();
        assert!((at30.1 - 12.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run_is_safe() {
        let r = RunResult::default();
        assert_eq!(r.successful(), 0);
        assert_eq!(r.cost_per_million_usd(), 0.0);
        assert_eq!(r.termination_rate(), 0.0);
        assert!(r.cost_series(10.0, 100.0).is_empty());
        let s = RunResult::new(MetricsMode::Streaming);
        assert_eq!(s.successful(), 0);
        assert_eq!(s.cost_per_million_usd(), 0.0);
        assert_eq!(s.latency_p50_ms(), 0.0);
        assert!(s.cost_series(10.0, 100.0).is_empty());
    }

    #[test]
    fn function_breakdown_aggregates() {
        let mut records = Vec::new();
        for i in 0..100u64 {
            let mut x = rec(i as f64 + 2.0, 2_000.0);
            x.submitted_at = SimTime::from_secs(i as f64);
            x.exec_ms = 1_000.0 + i as f64 * 10.0; // 1000..1990
            records.push(x);
        }
        let mut r = full_with(records, vec![cost(1.0, 2e-5)]);
        r.terminations = 5;
        for _ in 0..20 {
            r.record_bench(300.0);
        }
        r.cold_starts = 7;
        r.warm_hits = 93;
        r.threshold_ms = 410.0;
        let b = FunctionBreakdown::from_run(3, "weather-3", 100, &r);
        assert_eq!(b.function, 3);
        assert_eq!(b.successful, 100);
        assert_eq!(b.arrivals, 100);
        assert!((b.p50_exec_ms - 1_495.0).abs() < 1e-9);
        assert!((b.p95_exec_ms - 1_940.5).abs() < 1e-9);
        assert!((b.termination_rate - 0.25).abs() < 1e-12);
        assert!((b.total_cost_usd - 2e-5).abs() < 1e-18);
        assert!((b.cost_per_million_usd - 0.2).abs() < 1e-9);
        assert_eq!(b.threshold_ms, 410.0);
        assert!(b.p50_latency_ms > 0.0);
    }

    #[test]
    fn function_breakdown_of_empty_run() {
        let b = FunctionBreakdown::from_run(0, "idle", 0, &RunResult::default());
        assert_eq!(b.successful, 0);
        assert_eq!(b.p50_latency_ms, 0.0);
        assert_eq!(b.p95_exec_ms, 0.0);
        assert_eq!(b.termination_rate, 0.0);
    }

    #[test]
    fn region_breakdown_pools_functions() {
        let mut fast = RunResult::default();
        let mut slow = RunResult::default();
        for i in 0..10u64 {
            let mut a = rec(i as f64 + 1.0, 100.0);
            a.submitted_at = SimTime::from_secs(i as f64);
            fast.record_invocation(a);
            let mut b = rec(i as f64 + 3.0, 100.0);
            b.submitted_at = SimTime::from_secs(i as f64);
            slow.record_invocation(b);
        }
        fast.record_cost(cost(1.0, 1e-5));
        slow.record_cost(cost(1.0, 3e-5));
        slow.terminations = 2;
        let b = RegionBreakdown::from_runs(1, "iowa-1", 20, 4, 16, &[&fast, &slow]);
        assert_eq!(b.region, 1);
        assert_eq!(b.functions, 2);
        assert_eq!(b.arrivals, 20);
        assert_eq!(b.successful, 20);
        assert_eq!(b.terminations, 2);
        assert_eq!(b.cold_starts, 4);
        assert_eq!(b.warm_hits, 16);
        // Latencies pooled across both functions: half at 1 s, half 3 s.
        assert!((b.p50_latency_ms - 2_000.0).abs() < 1e-9);
        assert!(b.p95_latency_ms >= 3_000.0 - 1e-9);
        assert!((b.total_cost_usd - 4e-5).abs() < 1e-18);
        assert!((b.cost_per_million_usd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn region_breakdown_of_empty_region() {
        let b = RegionBreakdown::from_runs(0, "ghost", 0, 0, 0, &[]);
        assert_eq!(b.successful, 0);
        assert_eq!(b.cost_per_million_usd, 0.0);
        assert_eq!(b.p50_latency_ms, 0.0);
    }

    // -- streaming sink ---------------------------------------------------

    /// Push the same measurements into a full and a streaming sink.
    fn paired_sinks(n: u64) -> (RunResult, RunResult) {
        let mut full = RunResult::new(MetricsMode::Full);
        let mut stream = RunResult::new(MetricsMode::Streaming);
        for i in 0..n {
            let mut x = rec(i as f64 + 4.0, 1_800.0 + (i % 7) as f64 * 50.0);
            x.submitted_at = SimTime::from_secs(i as f64);
            x.exec_ms = 2_500.0 + (i % 13) as f64 * 40.0;
            full.record_invocation(x.clone());
            stream.record_invocation(x);
            let c = cost(i as f64 + 4.0, 1e-6 + i as f64 * 1e-9);
            full.record_cost(c);
            stream.record_cost(c);
            if i % 5 == 0 {
                full.record_bench(300.0 + i as f64);
                stream.record_bench(300.0 + i as f64);
            }
        }
        (full, stream)
    }

    #[test]
    fn streaming_counts_and_totals_are_exact() {
        let (full, stream) = paired_sinks(500);
        assert_eq!(stream.successful(), full.successful());
        assert_eq!(stream.bench_count(), full.bench_count());
        // Totals agree to fp accumulation order.
        assert!((stream.total_cost_usd() - full.total_cost_usd()).abs() < 1e-15);
        assert!(stream.records().is_empty(), "streaming keeps no records");
        assert!(stream.cost_events().is_empty());
    }

    #[test]
    fn streaming_stats_track_exact_aggregates() {
        let (full, stream) = paired_sinks(2_000);
        let m_rel = (stream.analysis_mean_ms() - full.analysis_mean_ms()).abs()
            / full.analysis_mean_ms();
        assert!(m_rel < 1e-9, "means diverged: rel {m_rel}");
        let p50_rel = (stream.latency_p50_ms() - full.latency_p50_ms()).abs()
            / full.latency_p50_ms();
        assert!(p50_rel < 0.05, "latency p50 diverged: rel {p50_rel}");
        let e95_rel =
            (stream.exec_p95_ms() - full.exec_p95_ms()).abs() / full.exec_p95_ms();
        assert!(e95_rel < 0.05, "exec p95 diverged: rel {e95_rel}");
    }

    #[test]
    fn streaming_cost_series_approximates_full() {
        let (full, stream) = paired_sinks(500);
        let f = full.cost_series(60.0, 600.0);
        let s = stream.cost_series(60.0, 600.0);
        assert!(!s.is_empty());
        // Same final running average (both cumulative over everything).
        let (_, f_last) = *f.last().unwrap();
        let (_, s_last) = *s.last().unwrap();
        assert!((f_last - s_last).abs() / f_last < 1e-9);
    }

    #[test]
    fn streaming_region_breakdown_pools_histograms() {
        let mut a = RunResult::new(MetricsMode::Streaming);
        let mut b = RunResult::new(MetricsMode::Streaming);
        for i in 0..200u64 {
            let mut x = rec(i as f64 + 1.0, 100.0); // 1 s latency
            x.submitted_at = SimTime::from_secs(i as f64);
            a.record_invocation(x);
            let mut y = rec(i as f64 + 3.0, 100.0); // 3 s latency
            y.submitted_at = SimTime::from_secs(i as f64);
            b.record_invocation(y);
        }
        let rb = RegionBreakdown::from_runs(0, "stream-0", 400, 2, 398, &[&a, &b]);
        assert_eq!(rb.successful, 400);
        // Histogram resolution is 200 ms: p50 within one bucket of 1–3 s
        // band boundary, p95 near 3 s.
        assert!(rb.p50_latency_ms >= 800.0 && rb.p50_latency_ms <= 3_200.0);
        assert!((rb.p95_latency_ms - 3_000.0).abs() <= 400.0);
    }

    #[test]
    fn streaming_cost_series_clips_partial_final_window() {
        // Horizon 90 s is not a multiple of the 60 s window: the event at
        // t=70 s (second window) must still be reported, stamped at the
        // horizon, not silently dropped with its window's 120 s end-stamp.
        let mut r = RunResult::new(MetricsMode::Streaming);
        let mut x = rec(70.0, 100.0);
        x.submitted_at = SimTime::from_secs(69.0);
        r.record_invocation(x);
        r.record_cost(cost(70.0, 7e-6));
        let s = r.cost_series(10.0, 90.0);
        let (t_last, v_last) = *s.last().unwrap();
        assert!((t_last - 90.0).abs() < 1e-9, "last stamp {t_last}");
        assert!((v_last - 7.0).abs() < 1e-9, "partial window dropped: {v_last}");
    }

    #[test]
    fn cost_windows_series_is_cumulative() {
        let mut w = CostWindows::new(60.0);
        w.record_cost(SimTime::from_secs(10.0), 5e-6);
        w.record_success(SimTime::from_secs(10.0));
        w.record_cost(SimTime::from_secs(70.0), 5e-6);
        w.record_success(SimTime::from_secs(70.0));
        let s = w.series_per_million();
        assert_eq!(s.len(), 2);
        assert!((s[0].1 - 5.0).abs() < 1e-9); // $5/M after one success
        assert!((s[1].1 - 5.0).abs() < 1e-9); // still $5/M average
        assert_eq!(s[0].0, 60.0);
        assert_eq!(s[1].0, 120.0);
    }

    // -- workload classes -------------------------------------------------

    fn class_row(cold: u64, warm: u64, p50_exec: f64, successful: u64) -> FunctionBreakdown {
        FunctionBreakdown {
            function: 0,
            name: "f".into(),
            arrivals: successful,
            successful,
            p50_latency_ms: 0.0,
            p95_latency_ms: 0.0,
            p50_exec_ms: p50_exec,
            p95_exec_ms: p50_exec,
            terminations: 1,
            termination_rate: 0.0,
            cold_starts: cold,
            warm_hits: warm,
            total_cost_usd: 1e-5,
            cost_per_million_usd: 0.0,
            threshold_ms: 0.0,
        }
    }

    #[test]
    fn workload_class_boundaries() {
        // 95% warm, long.
        let b = class_row(5, 95, 2_000.0, 100);
        assert_eq!(WorkloadClass::of(&b).label(), "hot/long");
        // Exactly at the hot boundary counts as hot.
        let b = class_row(10, 90, 100.0, 100);
        assert_eq!(WorkloadClass::of(&b).label(), "hot/short");
        let b = class_row(40, 60, LONG_EXEC_MS, 100);
        assert_eq!(WorkloadClass::of(&b).label(), "warm/long");
        // Mostly cold starts, short executions.
        let b = class_row(80, 20, 100.0, 100);
        assert_eq!(WorkloadClass::of(&b).label(), "cold/short");
        // No starts at all classifies as cold (nothing was ever warm).
        let b = class_row(0, 0, 100.0, 0);
        assert_eq!(WorkloadClass::of(&b).temp, TempClass::Cold);
        assert_eq!(WorkloadClass::all().len(), 6);
    }

    #[test]
    fn class_rollup_pools_members_and_skips_empty_classes() {
        let rows = vec![
            class_row(80, 20, 2_000.0, 100), // cold/long
            class_row(90, 10, 4_000.0, 300), // cold/long
            class_row(2, 98, 50.0, 50),      // hot/short
        ];
        let rollup = class_rollup(&rows);
        assert_eq!(rollup.len(), 2, "empty classes must be omitted");
        // Fixed order: hot/short before cold/long.
        assert_eq!(rollup[0].class.label(), "hot/short");
        assert_eq!(rollup[1].class.label(), "cold/long");
        let cl = &rollup[1];
        assert_eq!(cl.functions, 2);
        assert_eq!(cl.arrivals, 400);
        assert_eq!(cl.successful, 400);
        assert_eq!(cl.terminations, 2);
        assert_eq!(cl.cold_starts, 170);
        assert_eq!(cl.warm_hits, 30);
        assert!((cl.total_cost_usd - 2e-5).abs() < 1e-18);
        assert!((cl.cost_per_million_usd - 0.05).abs() < 1e-9);
        // Success-weighted: (2000*100 + 4000*300) / 400 = 3500.
        assert!((cl.mean_p50_exec_ms - 3_500.0).abs() < 1e-9);
        assert!(class_rollup(&[]).is_empty());
    }
}

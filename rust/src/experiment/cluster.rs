//! Multi-region, shared-node cluster replay.
//!
//! A trace whose records carry region ids is replayed against a
//! [`ClusterConfig`]: each region is an independent [`FaasPlatform`] (its
//! own variability regime, cold-start model, node pool and lottery), and
//! *within* a region every function the trace routes there deploys onto
//! the **shared** node pool — co-located instances contend on the same
//! node speed factors and the same instance quota, with isolated
//! per-function warm pools (`FaasPlatform::place_deploy`). This replaces
//! the one-isolated-platform-per-function shape of
//! `runner::run_trace` for cluster scenarios.
//!
//! Execution plan (all phases deterministic at any thread count):
//!
//! 0. **Routing** — the configured [`RoutingSpec`] assigns every trace
//!    record to a region in one admission-time pass (`policy::routing`;
//!    `TraceRegion` reproduces the trace's own ids bit-identically).
//! 1. **Pre-tests** — every `(region, function)` deployment calibrates its
//!    own elysium threshold on that region's platform (paper §II-B-a);
//!    the pairs are independent, so they fan out over
//!    `util::parallel::map_indexed`.
//! 2. **Sharding** — a second admission-time pass
//!    ([`policy_routing::assign_shards`]) splits every region's records
//!    into `cfg.shards` sub-streams, functions assigned whole by id
//!    rank. One shard per region (the default) is the unsharded engine.
//! 3. **Replay** — one [`RegionWorld`] sub-simulation per (region,
//!    shard), driven by the shared `sim` kernel; the sub-simulations
//!    share nothing, so the flat task list fans out over the worker pool
//!    and one hot region no longer pins a single core. Outcomes merge
//!    region-major, shard-minor, in canonical order. Each deployment
//!    owns a boxed [`SelectionPolicy`] built from its profile's spec (or
//!    the experiment default), so online thresholds and every other
//!    policy work inside cluster replays exactly as in
//!    single-deployment runs.

use anyhow::Result;

use crate::bound::AttemptSink;
use crate::coordinator::pretest::PretestReport;
use crate::coordinator::queue::{Invocation, InvocationQueue};
use crate::coordinator::MinosConfig;
use crate::obs::{GaugeSample, ObsData, ObsSink, ProbeEvent};
use crate::platform::{
    ClusterConfig, DeployId, FaasPlatform, InstanceId, Placement, RegionConfig, RegionId,
};
use crate::policy::{routing as policy_routing, RoutingSpec, SelectionPolicy};
use crate::sim::{EventQueue, SimTime, Simulation, World};
use crate::trace::{FunctionId, FunctionRegistry, Trace, TraceRecord};
use crate::util::parallel;
use crate::util::prng::{splitmix64, Rng};
use crate::workload::FunctionSpec;

use crate::fault::FailReason;

use super::config::ExperimentConfig;
use super::metrics::RunResult;
use super::runner::run_pretest;
use super::world::{
    adjudicate_requeue, build_policy, gate_and_start, settle_crash, settle_finish, ChurnState,
    CrashRecord, DeploymentCtx, FinishRecord, RecordPool, StartOutcome,
};

/// Domain events of a region sub-simulation. `slot` indexes the region's
/// deployment table. Like the single-deployment `Event`, the bulky
/// payloads are boxed to keep the enum within 64 bytes.
#[derive(Debug)]
enum CEvent {
    /// The `idx`-th arrival of the region's merged schedule (schedules
    /// its successor; no allocation per event).
    TraceArrival { idx: usize },
    /// Try to place the head of one deployment's queue.
    Dispatch { slot: u32 },
    /// A cold start finished; the instance begins serving `inv`.
    ColdReady { slot: u32, inst: InstanceId, inv: Invocation },
    /// A Minos-terminated instance crashes after its benchmark.
    CrashRequeue { slot: u32, inst: InstanceId, crash: Box<CrashRecord> },
    /// An invocation completed successfully.
    Finish { slot: u32, inst: InstanceId, rec: Box<FinishRecord> },
    /// An injected mid-flight fault kills this attempt partway through
    /// execution (`--fault-inflight`); nothing is billed.
    FaultCrash { slot: u32, inst: InstanceId, inv: Invocation },
    /// The next planned node death is due (`--faults weibull:…`).
    NodeFault,
}

/// One function's deployment inside a region.
#[derive(Debug)]
struct DeployState {
    function: FunctionId,
    name: String,
    spec: FunctionSpec,
    /// Minos config with the pre-tested threshold filled in.
    live_minos: MinosConfig,
    queue: InvocationQueue,
    result: RunResult,
    rng: Rng,
    /// This deployment's selection decision (fresh state per replay,
    /// seeded with the pre-tested threshold) — online policies included.
    policy: Box<dyn SelectionPolicy>,
    arrivals: usize,
    /// Last `policy.pushes()` value probed (per-deployment watch — the
    /// region recorder is shared, so the single-value watch in
    /// `Recorder::note_policy` would thrash across deployments).
    obs_last_pushes: u64,
    /// Per-deployment attempt recorder for the offline bounds (off by
    /// default; `cfg.record_attempts` turns it on). Each deployment owns
    /// its own sink so the log rides out on its own `RunResult`.
    rec: AttemptSink,
}

/// Probe invocation ids namespaced by deployment slot: each deployment's
/// queue numbers its own invocations from 0, so the raw ids collide
/// across a region's functions. Slot+1 in the high bits keeps a request's
/// termination/re-queue chain unique within the region track.
fn obs_inv_base(slot: u32) -> u64 {
    (slot as u64 + 1) << 40
}

/// A region's multi-function shared-node simulation state.
struct RegionWorld<'a> {
    cfg: &'a ExperimentConfig,
    platform: FaasPlatform,
    deploys: Vec<DeployState>,
    /// Merged `(time, slot, payload_scale)` arrival schedule, time-sorted.
    schedule: Vec<(SimTime, u32, f64)>,
    /// Free-list for the boxed event payloads (shared by the region's
    /// deployments — they interleave on one event queue).
    pool: RecordPool,
    /// The region's flight recorder (one track per region; off by
    /// default). Probes only observe — never schedule, never draw RNG.
    obs: ObsSink,
    /// The shard's dedicated fault/retry RNG (6000-family off the shard's
    /// own root, so every shard churns its own decorrelated stream).
    /// Nothing draws from it while the robustness knobs are at defaults.
    rng_fault: Rng,
    /// Node-churn state (`None` ⇔ `cfg.fault.spec` is off).
    churn: Option<ChurnState>,
    /// Replacement-node spawns eaten by `--fault-spawn` (platform-level:
    /// no single deployment owns a machine).
    spawn_failed: u64,
}

impl RegionWorld<'_> {
    fn start(
        &mut self,
        events: &mut EventQueue<CEvent>,
        now: SimTime,
        slot: u32,
        inst: InstanceId,
        inv: Invocation,
        cold: bool,
    ) {
        let Self { cfg, platform, deploys, pool, obs, rng_fault, .. } = self;
        let ds = &mut deploys[slot as usize];
        // Fault plane: sentence the attempt up front so the gate can
        // suppress the doomed benchmark sample (its report never arrives).
        let doomed = cfg.fault.inflight_p > 0.0 && rng_fault.f64() < cfg.fault.inflight_p;
        let outcome = gate_and_start(
            DeploymentCtx {
                spec: &ds.spec,
                minos: &ds.live_minos,
                policy: ds.policy.as_mut(),
                platform,
                result: &mut ds.result,
                rng: &mut ds.rng,
                pool,
                bench_warm: false,
                obs,
                obs_inv_base: obs_inv_base(slot),
                rec: &mut ds.rec,
            },
            now,
            inst,
            inv,
            cold,
            doomed,
        );
        match outcome {
            StartOutcome::Terminate { at, crash } => {
                events.schedule(at, CEvent::CrashRequeue { slot, inst, crash });
            }
            StartOutcome::Complete { at, rec } => {
                if doomed {
                    // Crash at a uniform point inside the exec window.
                    let frac = rng_fault.f64();
                    let at = SimTime(now.0 + ((at.0 - now.0) as f64 * frac) as u64);
                    events.schedule(at, CEvent::FaultCrash { slot, inst, inv: rec.inv });
                    pool.recycle_finish(rec);
                } else {
                    events.schedule(at, CEvent::Finish { slot, inst, rec });
                }
            }
        }
    }

    /// An in-flight attempt was killed by the fault plane: count it
    /// against its deployment and put the invocation back through the
    /// retry gate. Never billed.
    fn settle_fault_casualty(
        &mut self,
        events: &mut EventQueue<CEvent>,
        now: SimTime,
        slot: u32,
        inv: Invocation,
    ) {
        let ds = &mut self.deploys[slot as usize];
        ds.result.inflight_faults += 1;
        if let Some(delay_ms) = adjudicate_requeue(
            &self.cfg.retry,
            &mut ds.queue,
            &mut ds.result,
            &mut self.obs,
            obs_inv_base(slot),
            &mut self.rng_fault,
            now,
            inv,
        ) {
            events.schedule_in_ms(
                ds.live_minos.requeue_overhead_ms + delay_ms,
                CEvent::Dispatch { slot },
            );
        }
    }

    /// Execute every planned node death due now (mirrors the
    /// single-deployment world's handler; victims' in-flight events
    /// settle as fault casualties when they fire).
    fn process_churn(&mut self, now: SimTime, events: &mut EventQueue<CEvent>) {
        let Some(churn) = self.churn.as_mut() else { return };
        let mut due = std::mem::take(&mut churn.due);
        churn.plan.pop_due(now, &mut due);
        for death in due.drain(..) {
            let victim = churn.nodes[death.ordinal as usize];
            let mut victims = std::mem::take(&mut churn.victims);
            // Refuses stale ids and the last machine standing.
            if self.platform.fail_node(victim, &mut victims) {
                self.obs
                    .emit(now, ProbeEvent::NodeFault { victims: victims.len() as u64 });
                if self.obs.is_on() {
                    for v in &victims {
                        self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: v.0 });
                    }
                }
                if self.cfg.fault.spawn_fail_p > 0.0
                    && self.rng_fault.f64() < self.cfg.fault.spawn_fail_p
                {
                    self.obs.emit(now, ProbeEvent::SpawnFailed);
                    self.spawn_failed += 1;
                } else {
                    let fresh =
                        self.platform.spawn_node(self.cfg.day, &mut self.rng_fault, now);
                    let ordinal = churn.plan.add_node(now, &mut self.rng_fault);
                    debug_assert_eq!(ordinal as usize, churn.nodes.len());
                    churn.nodes.push(fresh);
                }
            }
            churn.victims = victims;
        }
        churn.due = due;
        if let Some(at) = churn.plan.next_at() {
            events.schedule(at.max(now), CEvent::NodeFault);
        }
    }
}

impl World for RegionWorld<'_> {
    type Event = CEvent;

    fn handle(
        &mut self,
        now: SimTime,
        ev: CEvent,
        events: &mut EventQueue<CEvent>,
    ) -> Result<()> {
        match ev {
            CEvent::TraceArrival { idx } => {
                let (_, slot, payload_scale) = self.schedule[idx];
                let adm =
                    self.deploys[slot as usize].queue.submit_scaled(0, payload_scale, now);
                self.obs.emit(
                    now,
                    ProbeEvent::Submitted {
                        inv: obs_inv_base(slot) | adm.inv.id,
                        attempt: adm.inv.retries,
                    },
                );
                // Sheds are terminal (the queue already counted them);
                // dispatch only runs when the arrival actually queued.
                if let Some(victim) = adm.evicted {
                    self.obs
                        .emit(now, ProbeEvent::Shed { inv: obs_inv_base(slot) | victim.id });
                }
                if adm.shed_new {
                    self.obs
                        .emit(now, ProbeEvent::Shed { inv: obs_inv_base(slot) | adm.inv.id });
                } else {
                    events.schedule(now, CEvent::Dispatch { slot });
                }
                if let Some(&(t_next, _, _)) = self.schedule.get(idx + 1) {
                    events.schedule(t_next, CEvent::TraceArrival { idx: idx + 1 });
                }
            }

            CEvent::Dispatch { slot } => {
                let Some(inv) = self.deploys[slot as usize].queue.take() else {
                    return Ok(());
                };
                let (expired0, recycled0) =
                    (self.platform.expired, self.platform.recycled);
                let placement = self.platform.place_deploy(DeployId(slot), now);
                if self.platform.expired > expired0 {
                    self.obs.emit(
                        now,
                        ProbeEvent::IdleExpired { count: self.platform.expired - expired0 },
                    );
                }
                if self.platform.recycled > recycled0 {
                    self.obs.emit(
                        now,
                        ProbeEvent::Recycled { count: self.platform.recycled - recycled0 },
                    );
                }
                match placement {
                    Placement::Warm(inst) => {
                        self.deploys[slot as usize].result.warm_hits += 1;
                        self.obs.emit(now, ProbeEvent::WarmHit { inst: inst.0 });
                        self.start(events, now, slot, inst, inv, false);
                    }
                    Placement::Cold { id, ready_at } => {
                        self.deploys[slot as usize].result.cold_starts += 1;
                        self.obs.emit(now, ProbeEvent::InstanceSpawned { inst: id.0 });
                        self.deploys[slot as usize]
                            .rec
                            .note_cold_spawn(id.0, ready_at.ms_since(now));
                        events.schedule(ready_at, CEvent::ColdReady { slot, inst: id, inv });
                    }
                    Placement::Saturated => {
                        // Shared quota exhausted (possibly by *another*
                        // function's fleet): back to the queue head and
                        // retry after the configurable saturation delay —
                        // unless the request's deadline already passed.
                        self.obs.emit(now, ProbeEvent::Saturated);
                        if self.cfg.retry.past_deadline(inv.submitted_at, now) {
                            self.obs.emit(
                                now,
                                ProbeEvent::RequestFailed {
                                    inv: obs_inv_base(slot) | inv.id,
                                    attempt: inv.retries,
                                    reason: FailReason::DeadlineExceeded,
                                },
                            );
                            let ds = &mut self.deploys[slot as usize];
                            ds.queue.fail(&inv);
                            ds.result.failed_deadline += 1;
                            // The quota may still fit a fresher request.
                            events.schedule(now, CEvent::Dispatch { slot });
                        } else {
                            self.deploys[slot as usize].queue.untake(inv);
                            events.schedule_in_ms(
                                self.cfg.retry.saturated_delay_ms,
                                CEvent::Dispatch { slot },
                            );
                        }
                    }
                }
            }

            CEvent::ColdReady { slot, inst, inv } => {
                // The node died while this cold start was booting.
                if !self.platform.scheduler.is_current(inst) {
                    self.settle_fault_casualty(events, now, slot, inv);
                    return Ok(());
                }
                self.platform.cold_start_ready(inst);
                // Spawn fault: the instance dies before it ever serves.
                if self.cfg.fault.spawn_fail_p > 0.0
                    && self.rng_fault.f64() < self.cfg.fault.spawn_fail_p
                {
                    if self.obs.is_on() {
                        self.obs.emit(now, ProbeEvent::SpawnFailed);
                        self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: inst.0 });
                    }
                    self.deploys[slot as usize].result.spawn_failed += 1;
                    self.platform.crash(inst);
                    self.settle_fault_casualty(events, now, slot, inv);
                    return Ok(());
                }
                self.start(events, now, slot, inst, inv, true);
            }

            CEvent::CrashRequeue { slot, inst, crash } => {
                // A node fault beat the scheduled termination: the attempt
                // is a plain fault casualty — nothing billed or terminated.
                if !self.platform.scheduler.is_current(inst) {
                    let inv = crash.inv;
                    self.pool.recycle_crash(crash);
                    self.settle_fault_casualty(events, now, slot, inv);
                    return Ok(());
                }
                if self.obs.is_on() {
                    let tagged = obs_inv_base(slot) | crash.inv.id;
                    self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: inst.0 });
                    self.obs.emit(
                        now,
                        ProbeEvent::Terminated {
                            inv: tagged,
                            attempt: crash.inv.retries,
                            bench_ms: crash.bench_ms,
                        },
                    );
                }
                self.platform.crash(inst);
                let inv = crash.inv;
                settle_crash(
                    &self.cfg.billing,
                    &mut self.deploys[slot as usize].result,
                    now,
                    &crash,
                );
                self.pool.recycle_crash(crash);
                let ds = &mut self.deploys[slot as usize];
                if let Some(delay_ms) = adjudicate_requeue(
                    &self.cfg.retry,
                    &mut ds.queue,
                    &mut ds.result,
                    &mut self.obs,
                    obs_inv_base(slot),
                    &mut self.rng_fault,
                    now,
                    inv,
                ) {
                    events.schedule_in_ms(
                        ds.live_minos.requeue_overhead_ms + delay_ms,
                        CEvent::Dispatch { slot },
                    );
                }
            }

            CEvent::Finish { slot, inst, rec } => {
                // The node died mid-execution: the completion never
                // happened — settle as a fault casualty instead.
                if !self.platform.scheduler.is_current(inst) {
                    let inv = rec.inv;
                    self.pool.recycle_finish(rec);
                    self.settle_fault_casualty(events, now, slot, inv);
                    return Ok(());
                }
                self.platform.release(inst, now);
                let ds = &mut self.deploys[slot as usize];
                // Pushed policy updates arrive between requests (§IV).
                ds.policy.on_request_complete();
                if self.obs.is_on() {
                    self.obs.emit(
                        now,
                        ProbeEvent::Finished {
                            inv: obs_inv_base(slot) | rec.inv.id,
                            attempt: rec.inv.retries,
                            cold: rec.cold,
                            e2e_ms: now.ms_since(rec.inv.submitted_at),
                        },
                    );
                    // Per-deployment push watch (no ThresholdUpdated
                    // probes here: each deployment publishes its own
                    // threshold, so a single-value watch would thrash).
                    let pushes = ds.policy.pushes();
                    if pushes > ds.obs_last_pushes {
                        self.obs.emit(
                            now,
                            ProbeEvent::PolicyPushes { count: pushes - ds.obs_last_pushes },
                        );
                        ds.obs_last_pushes = pushes;
                    }
                }
                settle_finish(&self.cfg.billing, &mut ds.result, &mut ds.queue, now, &rec, None);
                self.pool.recycle_finish(rec);
            }

            CEvent::FaultCrash { slot, inst, inv } => {
                // Injected mid-flight fault. If the node already died the
                // instance is gone; either way the attempt is a casualty.
                if self.platform.scheduler.is_current(inst) {
                    self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: inst.0 });
                    self.platform.crash(inst);
                }
                self.settle_fault_casualty(events, now, slot, inv);
            }

            CEvent::NodeFault => self.process_churn(now, events),
        }
        Ok(())
    }

    fn observe(&mut self, now: SimTime) {
        if !self.obs.is_on() {
            return;
        }
        self.obs.note_drift(now, self.platform.nodes().drift_epochs());
        if let Some(at) = self.obs.gauge_due(now) {
            let queue_depth: u64 = self.deploys.iter().map(|d| d.queue.len() as u64).sum();
            let completed: u64 = self.deploys.iter().map(|d| d.result.successful()).sum();
            let terminations: u64 =
                self.deploys.iter().map(|d| d.result.terminations).sum();
            let cost_usd: f64 =
                self.deploys.iter().map(|d| d.result.total_cost_usd()).sum();
            let failed: u64 = self.deploys.iter().map(|d| d.result.failed()).sum();
            let shed: u64 = self.deploys.iter().map(|d| d.queue.shed).sum();
            self.obs.record_gauge(GaugeSample {
                at,
                queue_depth,
                fleet: self.platform.fleet_gauges(),
                completed,
                terminations,
                cost_usd,
                failed,
                shed,
                node_faults: self.platform.node_faults,
            });
        }
    }
}

/// Per-deployment outcome of a cluster replay.
#[derive(Debug)]
pub struct DeploymentOutcome {
    pub region: RegionId,
    pub function: FunctionId,
    pub name: String,
    /// Arrivals the trace routed to this (region, function) deployment.
    pub arrivals: usize,
    /// This deployment's own threshold calibration.
    pub pretest: PretestReport,
    pub result: RunResult,
}

/// Per-region outcome: platform-level counters plus one entry per
/// deployed function.
#[derive(Debug)]
pub struct RegionOutcome {
    pub region: RegionId,
    pub region_name: String,
    /// Platform-wide counters (shared across the region's functions).
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub expired: u64,
    pub recycled: u64,
    pub crashes: u64,
    /// Fault-injected node deaths (0 unless `--faults` is on).
    pub node_faults: u64,
    /// Failed replacement-node spawns (platform-level; per-attempt cold
    /// spawn failures are counted in `RunResult::spawn_failed`).
    pub spawn_failed: u64,
    /// Events the region's sub-simulation handled (throughput metric).
    pub events_handled: u64,
    pub per_function: Vec<DeploymentOutcome>,
    /// Flight-recorder captures for this region, shard-index order
    /// (empty unless the replay was instrumented). Track label = the
    /// region name, or `{region}/s{shard}` when sharded.
    pub obs: Vec<Box<ObsData>>,
}

impl RegionOutcome {
    pub fn arrivals(&self) -> usize {
        self.per_function.iter().map(|f| f.arrivals).sum()
    }

    pub fn completed(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.successful()).sum()
    }

    pub fn terminations(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.terminations).sum()
    }

    pub fn cost_usd(&self) -> f64 {
        self.per_function.iter().map(|f| f.result.total_cost_usd()).sum()
    }

    /// Terminal failures (retry budget exhausted or deadline exceeded).
    pub fn failed(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.failed()).sum()
    }

    /// Arrivals shed at admission (bounded queue).
    pub fn shed(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.shed).sum()
    }
}

/// Outcome of a full cluster replay, regions in id order.
#[derive(Debug)]
pub struct ClusterOutcome {
    pub per_region: Vec<RegionOutcome>,
}

impl ClusterOutcome {
    pub fn total_arrivals(&self) -> usize {
        self.per_region.iter().map(RegionOutcome::arrivals).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.per_region.iter().map(RegionOutcome::completed).sum()
    }

    pub fn total_terminations(&self) -> u64 {
        self.per_region.iter().map(RegionOutcome::terminations).sum()
    }

    pub fn total_cost_usd(&self) -> f64 {
        self.per_region.iter().map(RegionOutcome::cost_usd).sum()
    }

    pub fn total_events_handled(&self) -> u64 {
        self.per_region.iter().map(|r| r.events_handled).sum()
    }

    /// The instrumented captures in canonical (region id, shard index)
    /// order — the order `run_cluster` merges worker results in, so
    /// timeline and gauge exports are byte-identical at any thread count.
    pub fn obs_tracks(&self) -> Vec<&ObsData> {
        self.per_region.iter().flat_map(|r| r.obs.iter().map(|d| &**d)).collect()
    }

    /// Every deployment's report row, region-major (the deterministic
    /// order `per_region` holds) — the input the workload-class rollup
    /// pools across regions.
    pub fn function_breakdowns(&self) -> Vec<crate::experiment::metrics::FunctionBreakdown> {
        self.per_region
            .iter()
            .flat_map(|r| {
                r.per_function.iter().map(|f| {
                    crate::experiment::metrics::FunctionBreakdown::from_run(
                        f.function.0,
                        &f.name,
                        f.arrivals as u64,
                        &f.result,
                    )
                })
            })
            .collect()
    }
}

/// Replay a multi-region trace against a cluster. `threads` follows the
/// crate convention (0 = auto, 1 = sequential); results are bit-identical
/// at any thread count. `base.routing` picks the admission-time routing
/// policy (default: honor the trace's region ids); `base.shards` splits
/// every region into that many independent sub-simulations (1 = the
/// unsharded engine, bit-identical to pre-sharding replays).
pub fn run_cluster(
    base: &ExperimentConfig,
    registry: &FunctionRegistry,
    trace: &Trace,
    cluster: &ClusterConfig,
    threads: usize,
) -> Result<ClusterOutcome> {
    anyhow::ensure!(!cluster.is_empty(), "cluster needs at least one region");
    let n_shards = base.shards.max(1) as usize;
    if n_shards > 1 {
        // Every shard carves a non-empty slice of its region's node pool;
        // a zero-node shard could never place anything and would spin on
        // dispatch retries forever.
        for region in cluster.iter() {
            anyhow::ensure!(
                region.platform.n_nodes >= n_shards,
                "region {} has {} nodes but shards={n_shards} needs at least one \
                 node per sub-pool",
                region.name,
                region.platform.n_nodes
            );
        }
    }
    // Refuse partial coverage, like `run_trace`: silently dropping records
    // would make the totals read as a complete replay.
    anyhow::ensure!(
        trace.n_functions() <= registry.len(),
        "trace addresses function ids up to {} but the registry defines only {} \
         profiles",
        trace.n_functions().saturating_sub(1),
        registry.len()
    );
    if base.routing == RoutingSpec::Trace {
        // Only trace routing consumes the trace's region ids; the other
        // policies re-route every record onto the cluster's regions.
        anyhow::ensure!(
            trace.n_regions() <= cluster.len(),
            "trace routes to region ids up to {} but the cluster defines only {} \
             regions",
            trace.n_regions().saturating_sub(1),
            cluster.len()
        );
    }

    // Phase 0: admission-time routing (one deterministic O(N) pass;
    // TraceRegion reproduces `records_by_region` exactly).
    let mut router = base.routing.build();
    let by_region =
        policy_routing::route_records(trace.records(), cluster.len(), router.as_mut())
            .map_err(anyhow::Error::msg)?;

    // Deployment tables: the function ids with arrivals per region,
    // ascending (= slot order inside the region world).
    let deployments: Vec<Vec<FunctionId>> = by_region
        .iter()
        .map(|records| {
            let mut ids: Vec<u32> = records.iter().map(|r| r.function.0).collect();
            ids.sort_unstable();
            ids.dedup();
            ids.into_iter().map(FunctionId).collect()
        })
        .collect();

    // Phase A: per-(region, function) threshold calibration, in parallel.
    let mut pretest_cfgs: Vec<ExperimentConfig> = Vec::new();
    let mut pretest_keys: Vec<(usize, FunctionId)> = Vec::new();
    for (r, fns) in deployments.iter().enumerate() {
        let region = cluster.get(RegionId(r as u32)).expect("dense region ids");
        for &f in fns {
            let profile = registry.get(f).expect("coverage ensured above");
            let mut cfg = base.clone();
            cfg.platform = region.platform.clone();
            cfg.function = profile.spec.clone();
            cfg.minos = profile.minos.clone();
            cfg.elysium_percentile = profile.elysium_percentile;
            cfg.open_loop_rate_rps = None;
            cfg.replay = None;
            // Every (region, function) deployment draws its own pre-test
            // lottery, derived deterministically from the master seed.
            cfg.seed = region
                .region_seed(base.seed)
                .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(f.0 as u64 + 1));
            pretest_cfgs.push(cfg);
            pretest_keys.push((r, f));
        }
    }
    let pretests: Vec<PretestReport> =
        parallel::try_map_indexed(pretest_cfgs.len(), threads, |i| {
            run_pretest(&pretest_cfgs[i], None)
        })?;
    let mut pretest_by_region: Vec<Vec<(FunctionId, PretestReport)>> =
        (0..cluster.len()).map(|_| Vec::new()).collect();
    for ((r, f), report) in pretest_keys.into_iter().zip(pretests) {
        pretest_by_region[r].push((f, report));
    }

    // Phase B: independent (region, shard) sub-simulations. The second
    // admission-time pass splits each region's records into shard
    // sub-streams (functions assigned whole); a shard's pretest list is
    // the region list filtered to its functions, which keeps the
    // ascending-function-id slot order. The flat task list load-balances
    // the whole cluster over the worker pool; outcomes merge
    // region-major, shard-minor, so results are bit-identical at any
    // thread count. With `n_shards == 1` every task sees exactly the
    // inputs the unsharded engine saw.
    let mut shard_records: Vec<Vec<TraceRecord>> =
        Vec::with_capacity(cluster.len() * n_shards);
    let mut shard_pretests: Vec<Vec<(FunctionId, PretestReport)>> =
        Vec::with_capacity(cluster.len() * n_shards);
    for (r, records) in by_region.iter().enumerate() {
        for recs in policy_routing::assign_shards(records, n_shards) {
            let mut fns: Vec<u32> = recs.iter().map(|rec| rec.function.0).collect();
            fns.sort_unstable();
            fns.dedup();
            shard_pretests.push(
                pretest_by_region[r]
                    .iter()
                    .filter(|(f, _)| fns.binary_search(&f.0).is_ok())
                    .cloned()
                    .collect(),
            );
            shard_records.push(recs);
        }
    }
    let shard_outcomes: Vec<RegionOutcome> =
        parallel::try_map_indexed(shard_records.len(), threads, |i| {
            let (r, k) = (i / n_shards, i % n_shards);
            run_region(
                base,
                cluster.get(RegionId(r as u32)).expect("dense region ids"),
                registry,
                &shard_pretests[i],
                &shard_records[i],
                ShardCtx { index: k as u32, count: n_shards as u32 },
            )
        })?;
    let mut shard_outcomes = shard_outcomes.into_iter();
    let per_region: Vec<RegionOutcome> = (0..cluster.len())
        .map(|_| merge_region_shards(shard_outcomes.by_ref().take(n_shards).collect()))
        .collect();
    Ok(ClusterOutcome { per_region })
}

/// One shard of a region's replay: `index` of `count` sub-pools. The
/// unsharded engine is the `count == 1` special case.
#[derive(Debug, Clone, Copy)]
struct ShardCtx {
    index: u32,
    count: u32,
}

/// Shard `index`'s slice of an `n`-item budget (nodes, instance quota):
/// a near-even split with the remainder going to the lowest-indexed
/// shards, total preserved.
fn shard_slice(n: usize, shard: ShardCtx) -> usize {
    let count = shard.count as usize;
    n / count + usize::from((shard.index as usize) < n % count)
}

/// Merge one region's shard outcomes (shard-index order) back into a
/// region-level outcome: platform counters sum, per-function rows
/// re-sort into the region's canonical ascending-function-id order (each
/// function lives in exactly one shard), obs captures concatenate in
/// shard order.
fn merge_region_shards(mut shards: Vec<RegionOutcome>) -> RegionOutcome {
    let mut merged = shards.remove(0);
    for s in shards {
        merged.cold_starts += s.cold_starts;
        merged.warm_hits += s.warm_hits;
        merged.expired += s.expired;
        merged.recycled += s.recycled;
        merged.crashes += s.crashes;
        merged.node_faults += s.node_faults;
        merged.spawn_failed += s.spawn_failed;
        merged.events_handled += s.events_handled;
        merged.per_function.extend(s.per_function);
        merged.obs.extend(s.obs);
    }
    merged.per_function.sort_by_key(|f| f.function.0);
    merged
}

/// Run one shard of a region's shared-node sub-simulation.
///
/// §Determinism: the `count == 1` arm reproduces the unsharded engine
/// bit-for-bit — same platform seed and salt, same RNG roots, same obs
/// track label. Sharded pools (`count > 1`) carve the node pool and
/// instance quota into near-even slices and mix the shard index into the
/// region seed: each shard is its own decorrelated sub-simulation, so
/// placement intentionally diverges from the unsharded replay (see
/// README, "Fleet scale") while staying bit-identical at any thread
/// count.
fn run_region(
    base: &ExperimentConfig,
    region: &RegionConfig,
    registry: &FunctionRegistry,
    pretests: &[(FunctionId, PretestReport)],
    records: &[TraceRecord],
    shard: ShardCtx,
) -> Result<RegionOutcome> {
    let (platform, root, track) = if shard.count <= 1 {
        (
            region.build_platform(base.day, base.seed, 0),
            Rng::new(region.region_seed(base.seed) ^ 0x9E3779B97F4A7C15),
            region.name.clone(),
        )
    } else {
        let mut pcfg = region.platform.clone();
        pcfg.n_nodes = shard_slice(region.platform.n_nodes, shard);
        pcfg.max_instances = shard_slice(region.platform.max_instances, shard).max(1);
        let mut mix = region.region_seed(base.seed)
            ^ (shard.index as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let seed = splitmix64(&mut mix);
        (
            FaasPlatform::new_salted(pcfg, base.day, seed, 0),
            Rng::new(seed ^ 0x9E3779B97F4A7C15),
            format!("{}/s{}", region.name, shard.index),
        )
    };

    let mut deploys = Vec::with_capacity(pretests.len());
    let mut slot_of: Vec<u32> = vec![u32::MAX; registry.len()];
    for (slot, (f, pretest)) in pretests.iter().enumerate() {
        let profile = registry.get(*f).expect("coverage ensured");
        let live_minos = MinosConfig {
            elysium_threshold_ms: pretest.threshold_ms,
            ..profile.minos.clone()
        };
        slot_of[f.0 as usize] = slot as u32;
        let mut result = RunResult::new(base.metrics);
        result.threshold_ms = live_minos.elysium_threshold_ms;
        // The deployment's policy: its profile's override, or the
        // experiment default — seeded with its own pre-tested threshold.
        let policy = build_policy(
            profile.policy.unwrap_or(base.policy),
            &live_minos,
            profile.elysium_percentile,
        );
        deploys.push(DeployState {
            function: *f,
            name: profile.name.clone(),
            spec: profile.spec.clone(),
            result,
            live_minos,
            queue: InvocationQueue::with_admission(base.admission),
            rng: root.fork(7_000 + base.day as u64 + slot as u64 * 31),
            policy,
            arrivals: 0,
            obs_last_pushes: 0,
            rec: AttemptSink::from_flag(base.record_attempts),
        });
    }

    let mut schedule = Vec::with_capacity(records.len());
    for r in records {
        let slot = slot_of[r.function.0 as usize];
        debug_assert_ne!(slot, u32::MAX, "record for undeployed function");
        deploys[slot as usize].arrivals += 1;
        schedule.push((r.t, slot, r.payload_scale));
    }

    // Per-shard fault stream: `root` is already shard-seed-mixed, so each
    // shard churns its own decorrelated slice of the node pool. Faults-off
    // draws nothing (fork reads the parent state without advancing it).
    let mut rng_fault = root.fork(6_000 + base.day as u64);
    let horizon = records.last().map_or(SimTime::ZERO, |r| r.t);
    let churn = ChurnState::build(base.fault.spec, &platform, horizon, &mut rng_fault);

    let mut sim = Simulation::new(RegionWorld {
        cfg: base,
        platform,
        deploys,
        schedule,
        pool: RecordPool::new(),
        obs: ObsSink::from_config(&base.obs),
        rng_fault,
        churn,
        spawn_failed: 0,
    });
    if let Some(&(t0, _, _)) = sim.world.schedule.first() {
        sim.events.schedule(t0, CEvent::TraceArrival { idx: 0 });
    }
    if let Some(at) = sim.world.churn.as_ref().and_then(|c| c.plan.next_at()) {
        sim.events.schedule(at, CEvent::NodeFault);
    }
    sim.run()?;
    let events_handled = sim.events_handled();
    let mut world = sim.into_world();
    let obs = world.obs.take_data(&track);

    let mut per_function = Vec::with_capacity(world.deploys.len());
    for (mut ds, (_, pretest)) in world.deploys.into_iter().zip(pretests) {
        debug_assert!(ds.queue.conserved(), "invocation conservation violated");
        debug_assert_eq!(ds.queue.failed, ds.result.failed(), "failure ledger divergence");
        ds.result.online_pushes = ds.policy.pushes();
        ds.result.shed = ds.queue.shed;
        ds.result.queue_peak_depth = ds.queue.peak_depth;
        ds.result.attempts = ds.rec.take_log();
        per_function.push(DeploymentOutcome {
            region: region.id,
            function: ds.function,
            name: ds.name,
            arrivals: ds.arrivals,
            pretest: pretest.clone(),
            result: ds.result,
        });
    }
    Ok(RegionOutcome {
        region: region.id,
        region_name: region.name.clone(),
        cold_starts: world.platform.cold_starts,
        warm_hits: world.platform.warm_hits,
        expired: world.platform.expired,
        recycled: world.platform.recycled,
        crashes: world.platform.crashes,
        node_faults: world.platform.node_faults,
        spawn_failed: world.spawn_failed,
        events_handled,
        per_function,
        obs: obs.into_iter().collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SynthConfig;

    fn demo_trace(n_regions: usize, seed: u64) -> Trace {
        SynthConfig {
            n_functions: 4,
            n_regions,
            hours: 0.05,
            total_rate_rps: 3.0,
            region_spill: 0.15,
            seed,
            ..Default::default()
        }
        .generate()
    }

    #[test]
    fn event_enum_stays_small() {
        assert!(
            std::mem::size_of::<CEvent>() <= 64,
            "hot CEvent enum grew to {} bytes",
            std::mem::size_of::<CEvent>()
        );
        // Queue entry = time + seq + event; bucket `Vec`s stay
        // cache-friendly only while this holds.
        assert!(
            crate::sim::event::entry_bytes::<CEvent>() <= 80,
            "queue entry grew to {} bytes",
            crate::sim::event::entry_bytes::<CEvent>()
        );
    }

    #[test]
    fn cluster_replay_completes_every_arrival() {
        let trace = demo_trace(2, 11);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(2);
        let cfg = ExperimentConfig::smoke(1, 77);
        let o = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        assert_eq!(o.per_region.len(), 2);
        assert_eq!(o.total_arrivals(), trace.len());
        assert_eq!(o.total_completed(), trace.len() as u64);
        assert!(o.total_cost_usd() > 0.0);
        assert!(o.total_events_handled() > trace.len() as u64);
        for r in &o.per_region {
            assert_eq!(
                r.arrivals(),
                trace.count_for_region(r.region),
                "region {} arrival accounting",
                r.region_name
            );
            for f in &r.per_function {
                assert_eq!(f.result.successful(), f.arrivals as u64);
                assert!(f.pretest.threshold_ms.is_finite() && f.pretest.threshold_ms > 0.0);
                assert_eq!(f.result.threshold_ms, f.pretest.threshold_ms);
            }
        }
        let rows = o.function_breakdowns();
        assert_eq!(
            rows.len(),
            o.per_region.iter().map(|r| r.per_function.len()).sum::<usize>()
        );
        assert_eq!(rows.iter().map(|b| b.arrivals).sum::<u64>(), trace.len() as u64);
    }

    #[test]
    fn cluster_replay_is_bit_identical_across_thread_counts() {
        let trace = demo_trace(3, 29);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(3);
        let cfg = ExperimentConfig::smoke(0, 99);
        let a = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        let b = run_cluster(&cfg, &registry, &trace, &cluster, 8).unwrap();
        assert_eq!(a.total_completed(), b.total_completed());
        assert_eq!(a.total_terminations(), b.total_terminations());
        assert_eq!(
            a.total_cost_usd().to_bits(),
            b.total_cost_usd().to_bits(),
            "thread count changed the replay"
        );
        for (ra, rb) in a.per_region.iter().zip(&b.per_region) {
            assert_eq!(ra.cold_starts, rb.cold_starts);
            assert_eq!(ra.events_handled, rb.events_handled);
            for (fa, fb) in ra.per_function.iter().zip(&rb.per_function) {
                assert_eq!(fa.result.records().len(), fb.result.records().len());
                for (x, y) in fa.result.records().iter().zip(fb.result.records()) {
                    assert_eq!(x.completed_at, y.completed_at);
                    assert_eq!(x.inv_id, y.inv_id);
                }
            }
        }
    }

    #[test]
    fn same_region_functions_share_one_node_pool() {
        // Two functions alternating on a one-node region: both fleets are
        // forced onto the same machine (the factor-sharing itself is
        // asserted in platform::platform::tests), and the shared platform
        // counters must account for every attempt of either fleet.
        let mut records = Vec::new();
        for i in 0..30 {
            records.push(TraceRecord {
                t: SimTime::from_ms(i as f64 * 4_000.0),
                function: FunctionId((i % 2) as u32),
                region: RegionId(0),
                payload_scale: 1.0,
            });
        }
        let trace = Trace::from_records(records);
        let registry = FunctionRegistry::demo(2);
        let mut region = RegionConfig::demo(0);
        region.platform.n_nodes = 1;
        let cluster = ClusterConfig::new(vec![region]);
        let cfg = ExperimentConfig::smoke(0, 5);
        let o = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        assert_eq!(o.total_completed(), 30);
        let r = &o.per_region[0];
        assert_eq!(r.per_function.len(), 2);
        // Both functions ran (interleaved) and the shared pool served
        // them: the region's platform counters cover both fleets.
        assert_eq!(r.cold_starts + r.warm_hits, 30 + r.terminations());
        for f in &r.per_function {
            assert!(f.result.successful() > 0);
        }
    }

    #[test]
    fn round_robin_routing_spreads_a_single_region_trace() {
        // The trace tags everything region 0; round-robin admission must
        // spread it across all three regions and still complete all of it.
        let trace = demo_trace(1, 33);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(3);
        let mut cfg = ExperimentConfig::smoke(0, 71);
        cfg.routing = RoutingSpec::RoundRobin;
        let o = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        assert_eq!(o.total_arrivals(), trace.len());
        assert_eq!(o.total_completed(), trace.len() as u64);
        for r in &o.per_region {
            assert!(r.arrivals() > 0, "region {} got no traffic", r.region_name);
        }
    }

    #[test]
    fn fastest_queue_routing_is_deterministic_across_threads() {
        let trace = demo_trace(2, 47);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(3);
        let mut cfg = ExperimentConfig::smoke(1, 72);
        cfg.routing = RoutingSpec::FastestQueue;
        let a = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        let b = run_cluster(&cfg, &registry, &trace, &cluster, 8).unwrap();
        assert_eq!(a.total_completed(), trace.len() as u64);
        assert_eq!(
            a.total_cost_usd().to_bits(),
            b.total_cost_usd().to_bits(),
            "thread count changed a fastest-queue replay"
        );
        // Routing beyond the trace's own region space is the point:
        // a 2-region trace may use all 3 cluster regions.
        assert_eq!(a.per_region.len(), 3);
    }

    #[test]
    fn online_policy_works_inside_cluster_replays() {
        // Arrivals spaced past the 10-minute idle timeout: every arrival
        // cold-starts, so the §IV collector sees a steady report stream
        // and must publish — the ROADMAP's "online thresholds inside
        // cluster replays" item.
        let records: Vec<TraceRecord> = (0..20)
            .map(|i| TraceRecord {
                t: SimTime::from_ms(i as f64 * 900_000.0),
                function: FunctionId(0),
                region: RegionId(0),
                payload_scale: 1.0,
            })
            .collect();
        let trace = Trace::from_records(records);
        let registry = FunctionRegistry::demo(1);
        let cluster = ClusterConfig::demo(1);
        let mut cfg = ExperimentConfig::smoke(1, 73);
        cfg.policy = crate::policy::PolicySpec::Online { update_every: 1 };
        let o = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        assert_eq!(o.total_completed(), 20);
        let pushes: u64 =
            o.per_region.iter().flat_map(|r| &r.per_function).map(|f| f.result.online_pushes).sum();
        assert!(pushes > 0, "online collector never published in a cluster replay");
    }

    #[test]
    fn sharded_replay_is_thread_invariant_and_complete() {
        let trace = demo_trace(2, 61);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(2);
        let mut cfg = ExperimentConfig::smoke(0, 88);
        cfg.shards = 4;
        let a = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        let b = run_cluster(&cfg, &registry, &trace, &cluster, 8).unwrap();
        assert_eq!(a.total_arrivals(), trace.len());
        assert_eq!(a.total_completed(), trace.len() as u64);
        assert_eq!(
            a.total_cost_usd().to_bits(),
            b.total_cost_usd().to_bits(),
            "thread count changed a sharded replay"
        );
        assert_eq!(a.total_events_handled(), b.total_events_handled());
        assert_eq!(a.total_terminations(), b.total_terminations());
        for (ra, rb) in a.per_region.iter().zip(&b.per_region) {
            assert_eq!(ra.cold_starts, rb.cold_starts);
            assert_eq!(ra.warm_hits, rb.warm_hits);
            // The merge restores the region's canonical slot order.
            assert!(
                ra.per_function.windows(2).all(|w| w[0].function.0 < w[1].function.0),
                "per-function rows out of order in {}",
                ra.region_name
            );
        }
    }

    #[test]
    fn shard_count_changes_placement_but_not_conservation() {
        let trace = demo_trace(1, 53);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(1);
        let mut cfg = ExperimentConfig::smoke(0, 44);
        let unsharded = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        cfg.shards = 2;
        let sharded = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        // Conservation holds either way: every arrival completes.
        assert_eq!(unsharded.total_completed(), trace.len() as u64);
        assert_eq!(sharded.total_completed(), trace.len() as u64);
        assert_eq!(sharded.total_arrivals(), unsharded.total_arrivals());
        // But the sub-pools draw their own node lotteries, so placement —
        // and with it the billed durations — intentionally diverges.
        assert_ne!(
            unsharded.total_cost_usd().to_bits(),
            sharded.total_cost_usd().to_bits(),
            "sharding left the placement stream untouched"
        );
    }

    #[test]
    fn shard_obs_tracks_are_namespaced() {
        let trace = demo_trace(1, 19);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cluster = ClusterConfig::demo(1);
        let mut cfg = ExperimentConfig::smoke(0, 21);
        cfg.obs = crate::obs::ObsConfig {
            level: crate::obs::Level::Summary,
            ring_cap: 1024,
            gauge_every: None,
        };
        let o = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        let tracks: Vec<&str> =
            o.obs_tracks().iter().map(|d| d.track.as_str()).collect();
        assert_eq!(tracks, vec![cluster.get(RegionId(0)).unwrap().name.as_str()]);
        cfg.shards = 2;
        let o = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap();
        let tracks: Vec<&str> =
            o.obs_tracks().iter().map(|d| d.track.as_str()).collect();
        assert_eq!(tracks.len(), 2, "one capture per shard");
        assert!(tracks[0].ends_with("/s0") && tracks[1].ends_with("/s1"), "{tracks:?}");
    }

    #[test]
    fn more_shards_than_nodes_is_an_error() {
        let trace = demo_trace(1, 13);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let mut region = RegionConfig::demo(0);
        region.platform.n_nodes = 1;
        let cluster = ClusterConfig::new(vec![region]);
        let mut cfg = ExperimentConfig::smoke(0, 9);
        cfg.shards = 2;
        let err = run_cluster(&cfg, &registry, &trace, &cluster, 1).unwrap_err();
        assert!(format!("{err:#}").contains("shards"), "unhelpful: {err:#}");
    }

    #[test]
    fn rejects_uncovered_regions_and_functions() {
        let trace = demo_trace(3, 11);
        let registry = FunctionRegistry::demo(trace.n_functions());
        let cfg = ExperimentConfig::smoke(0, 61);
        // Cluster smaller than the trace's region space.
        let err = run_cluster(&cfg, &registry, &trace, &ClusterConfig::demo(2), 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("region"), "unhelpful: {err:#}");
        // Registry smaller than the trace's function space.
        let small = FunctionRegistry::demo(1);
        let err = run_cluster(&cfg, &small, &trace, &ClusterConfig::demo(3), 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("registry"), "unhelpful: {err:#}");
    }
}

//! The single-deployment Minos world: the paper's experiment semantics as
//! a [`World`] implementation for the `sim` kernel.
//!
//! This is the domain half of what used to be one 850-line event loop in
//! `runner.rs`: virtual users → invocation queue → platform placement →
//! cold-start gate → function execution → billing (paper Figs. 1 and 2).
//! The kernel half (queue draining, clock, stop conditions) lives in
//! `sim::kernel`; the cold-start gate itself ([`gate_and_start`]) is
//! shared with the multi-function shared-node world in
//! `experiment::cluster`, so both worlds enforce identical semantics.
//!
//! *Which* instances the gate keeps is not decided here: every deployment
//! owns a boxed [`SelectionPolicy`] (built from the config's
//! [`PolicySpec`](crate::policy::PolicySpec) per run) and the gate only
//! orchestrates benchmark → `observe` → `judge`. The world tells the
//! policy when a request completes ([`SelectionPolicy::on_request_complete`])
//! — the moment online-threshold pushes take effect (§IV).
//!
//! The perf factor the gate hands a policy (via benchmark durations and
//! `JudgeCtx::perf_factor`) is the *contention-coupled* node speed when a
//! [`ContentionCurve`](crate::platform::ContentionCurve) is configured:
//! terminating an instance sheds load from its node and speeds the
//! survivors up, so online/epsilon policies judge against a target their
//! own verdicts move — the self-interference the paper's fixed-threshold
//! analysis hand-waves. With contention off (the default) the factor is
//! load-independent and the physics are pinned by the golden fingerprints.
//!
//! Timeline of one invocation attempt on an instance (times relative to
//! when the instance starts serving it):
//!
//! ```text
//! cold + Minos:   [ prepare (download) ───────────────┐
//!                 [ benchmark ──┬ judge               │
//!                               ├ fail: re-queue + crash (billed: bench)
//!                               └ pass ▼              ▼
//!                                      [ analysis ][ overhead ]  (billed:
//!                                  max(prepare, bench) + analysis + ovh)
//! cold baseline / forced / warm:
//!                 [ prepare ][ analysis ][ overhead ]
//! ```
//!
//! §Perf — the bulky per-invocation payloads ([`FinishRecord`],
//! [`CrashRecord`]) ride the event queue boxed (keeps `Event` ≤ 64 bytes)
//! and the boxes themselves are recycled through a [`RecordPool`]
//! free-list, so the steady-state hot path allocates nothing per
//! invocation.
//!
//! When a [`Runtime`] is supplied, every completed invocation *really*
//! executes the weather-regression HLO artifact through PJRT and the
//! prediction is verified against the Rust OLS oracle — the simulator
//! decides *when* things happen, the artifacts decide *what* is computed.

use anyhow::Result;

use crate::coordinator::lifecycle::{decide_cold_start_doomed, ColdStartDecision};
use crate::coordinator::queue::{Admission, Invocation, InvocationQueue};
use crate::coordinator::MinosConfig;
use crate::fault::{FailReason, FaultPlan, FaultSpec, PlannedDeath, RetryConfig, RetryDecision};
use crate::platform::{DeployId, FaasPlatform, InstanceId, NodeId, Placement};
use crate::policy::{BenchReport, PolicyInit, SelectionPolicy};
use crate::runtime::Runtime;
use crate::sim::{EventQueue, SimTime, World};
use crate::util::prng::Rng;
use crate::workload::weather;
use crate::workload::FunctionSpec;

use crate::bound::{AttemptOutcome, AttemptSink};
use crate::obs::{GaugeSample, ObsSink, ProbeEvent};

use super::config::ExperimentConfig;
use super::metrics::{CostEvent, InvocationRecord, RunResult};

/// Domain events of the single-deployment simulation.
///
/// The enum is a hot allocation unit — every event is pushed to and popped
/// from a binary heap by value — so the bulky per-invocation payloads
/// (`FinishRecord`, `CrashRecord`) are boxed to keep
/// `size_of::<Event>()` at or under 64 bytes (it was 104 with the records
/// inline; see `event_enum_stays_small`).
#[derive(Debug)]
pub(crate) enum Event {
    /// Open-loop mode: a Poisson arrival (schedules its own successor).
    Arrival,
    /// Trace-replay mode: the `idx`-th scheduled arrival (schedules its
    /// successor at the next trace timestamp — no allocation per event).
    TraceArrival { idx: usize },
    /// A virtual user submits a new request.
    Submit { vu: u32 },
    /// Try to place the queue head.
    Dispatch,
    /// A cold start finished; the instance begins serving `inv`.
    ColdReady { inst: InstanceId, inv: Invocation },
    /// A policy-terminated instance crashes after its benchmark; the
    /// invocation re-enters the queue.
    CrashRequeue { inst: InstanceId, crash: Box<CrashRecord> },
    /// An invocation completed successfully.
    Finish { inst: InstanceId, rec: Box<FinishRecord> },
    /// An injected mid-flight fault kills this attempt partway through
    /// execution (`--fault-inflight`); the invocation re-enters the retry
    /// gate and nothing is billed.
    FaultCrash { inst: InstanceId, inv: Invocation },
    /// The next planned node death is due (`--faults weibull:…`); the
    /// handler pops every death due now and reschedules itself.
    NodeFault,
}

/// Payload of a termination: the invocation to re-queue and the billed
/// benchmark duration (Fig. 3's d_term).
#[derive(Debug, Clone)]
pub(crate) struct CrashRecord {
    pub inv: Invocation,
    pub bench_ms: f64,
}

/// Everything needed to finalize a successful invocation at completion.
#[derive(Debug, Clone)]
pub(crate) struct FinishRecord {
    pub inv: Invocation,
    pub cold: bool,
    pub forced: bool,
    pub prepare_ms: f64,
    pub analysis_ms: f64,
    pub exec_ms: f64,
    pub bench_ms: Option<f64>,
}

/// Free-list of spent event-payload boxes (ROADMAP: the last 2
/// allocations per invocation on the hot path). The gate takes boxes
/// from here; the world returns them after settling the event. Both
/// record types are heap-flat, so re-initializing a recycled box is a
/// plain store. Capped so a burst cannot pin unbounded memory.
#[derive(Debug, Default)]
pub(crate) struct RecordPool {
    finish: Vec<Box<FinishRecord>>,
    crash: Vec<Box<CrashRecord>>,
}

/// Retained spent boxes per record kind; beyond this they fall back to
/// the allocator. 4096 covers every in-flight event the bucket ring
/// sizes for.
const RECORD_POOL_CAP: usize = 4_096;

impl RecordPool {
    pub fn new() -> RecordPool {
        RecordPool::default()
    }

    /// Box a finish payload, reusing a spent box when one is free.
    pub fn alloc_finish(&mut self, rec: FinishRecord) -> Box<FinishRecord> {
        match self.finish.pop() {
            Some(mut b) => {
                *b = rec;
                b
            }
            None => Box::new(rec),
        }
    }

    /// Box a crash payload, reusing a spent box when one is free.
    pub fn alloc_crash(&mut self, rec: CrashRecord) -> Box<CrashRecord> {
        match self.crash.pop() {
            Some(mut b) => {
                *b = rec;
                b
            }
            None => Box::new(rec),
        }
    }

    /// Return a settled finish box to the free-list.
    pub fn recycle_finish(&mut self, b: Box<FinishRecord>) {
        if self.finish.len() < RECORD_POOL_CAP {
            self.finish.push(b);
        }
    }

    /// Return a settled crash box to the free-list.
    pub fn recycle_crash(&mut self, b: Box<CrashRecord>) {
        if self.crash.len() < RECORD_POOL_CAP {
            self.crash.push(b);
        }
    }

    /// Boxes currently pooled (test hook).
    #[cfg(test)]
    pub fn pooled(&self) -> (usize, usize) {
        (self.finish.len(), self.crash.len())
    }
}

/// Disjoint borrows of one deployment's state, as [`gate_and_start`]
/// needs them. Both worlds (single-deployment, shared-node region) call
/// the gate through this bundle so the semantics — RNG draw order
/// included — are identical.
pub(crate) struct DeploymentCtx<'a> {
    pub spec: &'a FunctionSpec,
    pub minos: &'a MinosConfig,
    pub policy: &'a mut dyn SelectionPolicy,
    pub platform: &'a mut FaasPlatform,
    pub result: &'a mut RunResult,
    pub rng: &'a mut Rng,
    pub pool: &'a mut RecordPool,
    pub bench_warm: bool,
    /// Flight-recorder sink (observation only: the gate emits
    /// `AttemptStarted` / `GateVerdict` probes through it, never draws
    /// RNG for it, and `ObsSink::Off` reduces every emit to one
    /// discriminant test).
    pub obs: &'a mut ObsSink,
    /// High bits OR-ed into probe invocation ids. Cluster regions
    /// namespace per-deployment queues by slot (each deployment numbers
    /// its own invocations from 0); the single-deployment world passes 0.
    pub obs_inv_base: u64,
    /// Attempt-log recorder for the offline optimality bounds
    /// (`bound::record`). Same discipline as `obs`: observation only,
    /// never draws RNG, and `AttemptSink::Off` reduces every record call
    /// to one discriminant test.
    pub rec: &'a mut AttemptSink,
}

/// What an instance does after the cold-start gate, as schedulable facts.
pub(crate) enum StartOutcome {
    /// The policy terminated the instance: crash at `at`, re-queue the
    /// carried invocation.
    Terminate { at: SimTime, crash: Box<CrashRecord> },
    /// The invocation runs to completion at `at`.
    Complete { at: SimTime, rec: Box<FinishRecord> },
}

/// An instance begins serving an invocation (paper Fig. 2's flow): sample
/// the phase durations, run the cold-start gate (benchmark + policy
/// judgment) when `cold`, and decide when and how the attempt ends.
///
/// `doomed` marks an attempt the fault plane has already sentenced to a
/// mid-flight crash: the gate still runs (and bills) the benchmark, but
/// the sample never reaches the policy collector — a crashed attempt
/// never reports back. The caller converts a doomed `Complete` outcome
/// into a [`Event::FaultCrash`]-style termination.
pub(crate) fn gate_and_start(
    ctx: DeploymentCtx<'_>,
    now: SimTime,
    inst: InstanceId,
    mut inv: Invocation,
    cold: bool,
    doomed: bool,
) -> StartOutcome {
    let DeploymentCtx {
        spec,
        minos,
        policy,
        platform,
        result,
        rng,
        pool,
        bench_warm,
        obs,
        obs_inv_base,
        rec,
    } = ctx;
    obs.emit(
        now,
        ProbeEvent::AttemptStarted {
            inv: obs_inv_base | inv.id,
            attempt: inv.retries,
            inst: inst.0,
            cold,
        },
    );
    let perf = platform.perf_factor(inst, now);
    let noise = platform.invocation_noise();
    let phases = spec.sample_scaled(perf, noise, inv.payload_scale, rng);

    if cold {
        let draw = rng.f64();
        let decision = decide_cold_start_doomed(minos, policy, &inv, perf, draw, doomed, || {
            let b = minos.benchmark.duration_ms(perf, rng);
            result.record_bench(b);
            b
        });
        match decision {
            ColdStartDecision::TerminateAndRequeue { bench_ms } => {
                if obs.is_on() {
                    obs.emit(
                        now,
                        ProbeEvent::GateVerdict {
                            inv: obs_inv_base | inv.id,
                            attempt: inv.retries,
                            bench_ms,
                            threshold_ms: policy.published_threshold(),
                            pass: false,
                            forced: false,
                        },
                    );
                }
                platform.scheduler.get_mut(inst).benchmark_score = Some(bench_ms);
                if rec.is_on() {
                    rec.record(
                        now,
                        inst.0,
                        inv.id,
                        inv.retries,
                        inv.submitted_at,
                        perf,
                        true,
                        Some(bench_ms),
                        phases.prepare_ms,
                        phases.analysis_ms,
                        phases.overhead_ms,
                        AttemptOutcome::Terminated,
                    );
                }
                return StartOutcome::Terminate {
                    at: now.plus_ms(bench_ms),
                    crash: pool.alloc_crash(CrashRecord { inv, bench_ms }),
                };
            }
            ColdStartDecision::Run { forced, bench_ms } => {
                // No verdict probe for the baseline (no gate ran); the
                // forced pass records NaN for its skipped benchmark.
                if obs.is_on() && (forced || bench_ms.is_some()) {
                    obs.emit(
                        now,
                        ProbeEvent::GateVerdict {
                            inv: obs_inv_base | inv.id,
                            attempt: inv.retries,
                            bench_ms: bench_ms.unwrap_or(f64::NAN),
                            threshold_ms: policy.published_threshold(),
                            pass: true,
                            forced,
                        },
                    );
                }
                if forced {
                    inv.forced_pass = true;
                    result.forced_passes += 1;
                }
                if let Some(b) = bench_ms {
                    platform.scheduler.get_mut(inst).benchmark_score = Some(b);
                }
                // Analysis starts once both prepare and (any) benchmark are
                // done; the benchmark usually hides inside the download.
                let gate_ms = match bench_ms {
                    Some(b) => phases.prepare_ms.max(b),
                    None => phases.prepare_ms,
                };
                let exec_ms = gate_ms + phases.analysis_ms + phases.overhead_ms;
                if rec.is_on() {
                    rec.record(
                        now,
                        inst.0,
                        inv.id,
                        inv.retries,
                        inv.submitted_at,
                        perf,
                        true,
                        bench_ms,
                        phases.prepare_ms,
                        phases.analysis_ms,
                        phases.overhead_ms,
                        if doomed {
                            AttemptOutcome::Crashed
                        } else if forced {
                            AttemptOutcome::Forced
                        } else {
                            AttemptOutcome::Kept
                        },
                    );
                }
                return StartOutcome::Complete {
                    at: now.plus_ms(exec_ms),
                    rec: pool.alloc_finish(FinishRecord {
                        inv,
                        cold: true,
                        forced,
                        prepare_ms: phases.prepare_ms,
                        analysis_ms: phases.analysis_ms,
                        exec_ms,
                        bench_ms,
                    }),
                };
            }
        }
    }

    // Warm path: no gate. During the pre-test (`bench_warm`) the benchmark
    // still runs — purely to collect scores; it never terminates a warm
    // instance and its duration hides inside prepare.
    let bench_ms = if bench_warm && policy.benchmarks() {
        let b = minos.benchmark.duration_ms(perf, rng);
        result.record_bench(b);
        if !doomed {
            policy.observe(BenchReport { score_ms: b, warm: true });
        }
        Some(b)
    } else {
        None
    };
    let gate_ms = match bench_ms {
        Some(b) => phases.prepare_ms.max(b),
        None => phases.prepare_ms,
    };
    let exec_ms = gate_ms + phases.analysis_ms + phases.overhead_ms;
    if rec.is_on() {
        rec.record(
            now,
            inst.0,
            inv.id,
            inv.retries,
            inv.submitted_at,
            perf,
            false,
            bench_ms,
            phases.prepare_ms,
            phases.analysis_ms,
            phases.overhead_ms,
            if doomed { AttemptOutcome::Crashed } else { AttemptOutcome::Kept },
        );
    }
    StartOutcome::Complete {
        at: now.plus_ms(exec_ms),
        rec: pool.alloc_finish(FinishRecord {
            inv,
            cold: false,
            forced: false,
            prepare_ms: phases.prepare_ms,
            analysis_ms: phases.analysis_ms,
            exec_ms,
            bench_ms,
        }),
    }
}

/// Settle a termination (shared by both worlds): bill the crashed attempt
/// (Fig. 3's d_term) and count it. The caller crashes the instance on its
/// platform, then puts the invocation through [`adjudicate_requeue`] and
/// schedules the post-requeue dispatch.
pub(crate) fn settle_crash(
    billing: &crate::platform::billing::Billing,
    result: &mut RunResult,
    now: SimTime,
    crash: &CrashRecord,
) {
    result.record_cost(CostEvent {
        at: now,
        usd: billing.invocation_cost_usd(crash.bench_ms),
        terminated: true,
    });
    result.terminations += 1;
}

/// Put an in-flight invocation that needs another attempt (Minos
/// termination, fault casualty) through the unified retry gate (shared by
/// both worlds). On `Retry` it re-enters its queue and the backoff delay
/// comes back for the caller to add to its dispatch schedule; on `Fail`
/// it leaves the system as a counted terminal failure and `None` comes
/// back. With the default [`RetryConfig`] this always retries with zero
/// delay and draws nothing — bit-identical to the historical unbounded
/// requeue loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn adjudicate_requeue(
    retry: &RetryConfig,
    queue: &mut InvocationQueue,
    result: &mut RunResult,
    obs: &mut ObsSink,
    obs_inv_base: u64,
    rng_fault: &mut Rng,
    now: SimTime,
    inv: Invocation,
) -> Option<f64> {
    match retry.on_requeue(inv.retries, inv.submitted_at, now, rng_fault) {
        RetryDecision::Retry { delay_ms } => {
            if obs.is_on() {
                obs.emit(
                    now,
                    ProbeEvent::RetryScheduled {
                        inv: obs_inv_base | inv.id,
                        attempt: inv.retries + 1,
                        delay_ms,
                    },
                );
                // `requeue` bumps the retry count — probe the next attempt.
                obs.emit(
                    now,
                    ProbeEvent::Requeued { inv: obs_inv_base | inv.id, attempt: inv.retries + 1 },
                );
            }
            queue.requeue(inv);
            Some(delay_ms)
        }
        RetryDecision::Fail(reason) => {
            obs.emit(
                now,
                ProbeEvent::RequestFailed {
                    inv: obs_inv_base | inv.id,
                    attempt: inv.retries,
                    reason,
                },
            );
            queue.fail(&inv);
            match reason {
                FailReason::DeadlineExceeded => result.failed_deadline += 1,
                _ => result.failed_exhausted += 1,
            }
            None
        }
    }
}

/// Settle a successful completion (shared by both worlds): account the
/// invocation as complete, bill the executed duration, and record it. The
/// caller releases the instance to its warm pool.
pub(crate) fn settle_finish(
    billing: &crate::platform::billing::Billing,
    result: &mut RunResult,
    queue: &mut InvocationQueue,
    now: SimTime,
    rec: &FinishRecord,
    prediction: Option<f32>,
) {
    queue.complete(&rec.inv);
    result.record_cost(CostEvent {
        at: now,
        usd: billing.invocation_cost_usd(rec.exec_ms),
        terminated: false,
    });
    result.record_invocation(finish_record(rec, now, prediction));
}

/// Build an [`InvocationRecord`] from a finish payload (shared by both
/// worlds).
pub(crate) fn finish_record(
    rec: &FinishRecord,
    completed_at: SimTime,
    prediction: Option<f32>,
) -> InvocationRecord {
    InvocationRecord {
        inv_id: rec.inv.id,
        vu: rec.inv.vu,
        submitted_at: rec.inv.submitted_at,
        completed_at,
        attempts: rec.inv.retries + 1,
        forced: rec.forced,
        cold: rec.cold,
        prepare_ms: rec.prepare_ms,
        analysis_ms: rec.analysis_ms,
        exec_ms: rec.exec_ms,
        bench_ms: rec.bench_ms,
        prediction,
    }
}

/// Build the deployment's selection policy for one run: the configured
/// spec when Minos is enabled, the baseline [`NeverTerminate`] otherwise
/// (so the paired baseline arm is identical under *any* `--policy`).
///
/// [`NeverTerminate`]: crate::policy::NeverTerminate
pub(crate) fn build_policy(
    spec: crate::policy::PolicySpec,
    minos: &MinosConfig,
    percentile: f64,
) -> Box<dyn SelectionPolicy> {
    if minos.enabled {
        spec.build(PolicyInit { threshold_ms: minos.elysium_threshold_ms, percentile })
    } else {
        Box::new(crate::policy::NeverTerminate)
    }
}

/// Node-churn bookkeeping for one platform (shared by both worlds): the
/// seeded death plan, the ordinal → [`NodeId`] map it is keyed by, and
/// reusable scratch. Built only when the churn spec is on; the plan's
/// draws come from the owning world's fault stream in a fixed order, so
/// churn is a pure function of `(seed, day, salt)`.
pub(crate) struct ChurnState {
    pub plan: FaultPlan,
    /// `NodeId` by spawn ordinal (initial pool in slot order, then
    /// replacements in spawn order) — mirrors the plan's key space.
    pub nodes: Vec<NodeId>,
    /// Scratch for deaths due at one instant.
    pub due: Vec<PlannedDeath>,
    /// Scratch for the instances resident on a dying node.
    pub victims: Vec<InstanceId>,
}

impl ChurnState {
    /// Draw the initial pool's lifetimes from the fault stream; `None`
    /// when the churn spec is off (no fault state, no draws).
    pub(crate) fn build(
        spec: FaultSpec,
        platform: &FaasPlatform,
        horizon: SimTime,
        rng: &mut Rng,
    ) -> Option<ChurnState> {
        let nodes = platform.nodes().ids();
        let plan = FaultPlan::build(spec, nodes.len(), horizon, rng)?;
        Some(ChurnState { plan, nodes, due: Vec::new(), victims: Vec::new() })
    }
}

/// The paper's single-deployment experiment as a kernel [`World`]: one
/// function, one platform, closed-loop VUs / open-loop Poisson arrivals /
/// deterministic trace replay.
pub(crate) struct MinosWorld<'a> {
    cfg: &'a ExperimentConfig,
    runtime: Option<&'a Runtime>,
    bench_warm: bool,
    pub platform: FaasPlatform,
    queue: InvocationQueue,
    pub result: RunResult,
    rng_workload: Rng,
    /// The selection decision for this deployment (fresh state per run).
    policy: Box<dyn SelectionPolicy>,
    minos: MinosConfig,
    pool: RecordPool,
    /// Per-VU weather dataset (location) for real execution.
    datasets: Vec<weather::WeatherData>,
    /// Round-robin dataset assignment for open-loop/replay arrivals.
    arrival_rr: u32,
    /// Flight recorder (off by default; `cfg.obs` turns it on). Probes
    /// only observe — they never schedule events or draw RNG.
    obs: ObsSink,
    /// Dedicated fault/retry RNG (6000-family substream): churn
    /// lifetimes, doom and spawn-failure draws, backoff jitter. With
    /// every robustness knob at its default nothing ever draws from it,
    /// so the default configuration stays bit-identical to the pre-fault
    /// engine; with faults on it is a pure function of `(seed, day,
    /// salt)`, independent of thread scheduling.
    rng_fault: Rng,
    /// Node-churn state (`None` ⇔ `cfg.fault.spec` is off).
    churn: Option<ChurnState>,
    /// Attempt recorder for the offline bounds (off by default;
    /// `cfg.record_attempts` turns it on). Draws nothing, like `obs`.
    rec: AttemptSink,
}

impl<'a> MinosWorld<'a> {
    /// Build the world for one condition. `salt` separates the placement
    /// lottery between pre-test and main runs; paired conditions use the
    /// same salt. `runtime` enables real artifact execution per completed
    /// invocation.
    pub fn new(
        cfg: &'a ExperimentConfig,
        minos: &MinosConfig,
        salt: u64,
        bench_warm: bool,
        runtime: Option<&'a Runtime>,
    ) -> MinosWorld<'a> {
        let platform =
            FaasPlatform::new_salted(cfg.platform.clone(), cfg.day, cfg.seed, salt);
        let root = Rng::new(cfg.seed ^ 0x9E3779B97F4A7C15);
        let rng_workload = root.fork(7_000 + cfg.day as u64 + salt * 31);
        let policy = build_policy(cfg.policy, minos, cfg.elysium_percentile);
        let datasets: Vec<weather::WeatherData> = if runtime.is_some() {
            (0..cfg.vus.n_vus)
                .map(|vu| weather::generate(cfg.seed ^ (vu as u64) << 32))
                .collect()
        } else {
            Vec::new()
        };
        let mut result = RunResult::new(cfg.metrics);
        result.threshold_ms = minos.elysium_threshold_ms;
        // The fault stream exists even when faults are off (constructing
        // an RNG draws nothing); churn state only when the spec is on.
        // Deaths stop at the submission horizon so the event loop drains.
        let mut rng_fault = root.fork(6_000 + cfg.day as u64 + salt * 101);
        let horizon = match &cfg.replay {
            Some(s) => s
                .arrivals
                .last()
                .map_or(cfg.vus.horizon, |&(t, _)| t.max(cfg.vus.horizon)),
            None => cfg.vus.horizon,
        };
        let churn = ChurnState::build(cfg.fault.spec, &platform, horizon, &mut rng_fault);
        MinosWorld {
            cfg,
            runtime,
            bench_warm,
            platform,
            queue: InvocationQueue::with_admission(cfg.admission),
            result,
            rng_workload,
            policy,
            minos: minos.clone(),
            pool: RecordPool::new(),
            datasets,
            arrival_rr: 0,
            obs: ObsSink::from_config(&cfg.obs),
            rng_fault,
            churn,
            rec: AttemptSink::from_flag(cfg.record_attempts),
        }
    }

    /// Schedule the workload driver's initial events.
    pub fn seed_initial(&self, events: &mut EventQueue<Event>) {
        if let Some(churn) = &self.churn {
            if let Some(at) = churn.plan.next_at() {
                events.schedule(at, Event::NodeFault);
            }
        }
        if let Some(schedule) = &self.cfg.replay {
            // Trace replay: arrivals happen exactly when the trace says.
            if let Some(&(t0, _)) = schedule.arrivals.first() {
                events.schedule(t0, Event::TraceArrival { idx: 0 });
            }
        } else {
            match self.cfg.open_loop_rate_rps {
                // Open loop: one Poisson arrival process drives the queue.
                Some(rate) => {
                    assert!(rate > 0.0, "open-loop rate must be positive");
                    events.schedule(SimTime::ZERO, Event::Arrival);
                }
                // Closed loop (the paper's load generator): all VUs submit
                // at t=0.
                None => {
                    for vu in 0..self.cfg.vus.n_vus {
                        events.schedule(SimTime::ZERO, Event::Submit { vu });
                    }
                }
            }
        }
    }

    /// Tear down after the run: fold the platform counters into the
    /// result and hand it out. Any flight-recorder capture rides out on
    /// `RunResult::obs` under a generic track label; callers that know
    /// the run's identity (function name, day/arm) relabel it.
    pub fn finish(mut self) -> RunResult {
        debug_assert!(self.queue.conserved(), "invocation conservation violated");
        self.result.obs = self.obs.take_data("run");
        self.result.attempts = self.rec.take_log();
        let mut result = self.result;
        result.cold_starts = self.platform.cold_starts;
        result.warm_hits = self.platform.warm_hits;
        result.expired = self.platform.expired;
        result.recycled = self.platform.recycled;
        result.online_pushes = self.policy.pushes();
        result.shed = self.queue.shed;
        result.queue_peak_depth = self.queue.peak_depth;
        result.node_faults = self.platform.node_faults;
        debug_assert_eq!(
            self.queue.failed,
            result.failed(),
            "queue/result terminal-failure split diverged"
        );
        result
    }

    fn start_invocation(
        &mut self,
        events: &mut EventQueue<Event>,
        now: SimTime,
        inst: InstanceId,
        inv: Invocation,
        cold: bool,
    ) {
        let Self {
            cfg,
            minos,
            policy,
            platform,
            result,
            rng_workload,
            pool,
            bench_warm,
            obs,
            rng_fault,
            rec,
            ..
        } = self;
        // Fault plane: sentence the attempt up front so the gate can
        // suppress the doomed benchmark sample (its report never arrives).
        let doomed = cfg.fault.inflight_p > 0.0 && rng_fault.f64() < cfg.fault.inflight_p;
        let outcome = gate_and_start(
            DeploymentCtx {
                spec: &cfg.function,
                minos: &*minos,
                policy: policy.as_mut(),
                platform,
                result,
                rng: rng_workload,
                pool,
                bench_warm: *bench_warm,
                obs,
                obs_inv_base: 0,
                rec,
            },
            now,
            inst,
            inv,
            cold,
            doomed,
        );
        match outcome {
            StartOutcome::Terminate { at, crash } => {
                events.schedule(at, Event::CrashRequeue { inst, crash });
            }
            StartOutcome::Complete { at, rec } => {
                if doomed {
                    // Crash at a uniform point inside the exec window; the
                    // finish never happens.
                    let frac = rng_fault.f64();
                    let at = SimTime(now.0 + ((at.0 - now.0) as f64 * frac) as u64);
                    events.schedule(at, Event::FaultCrash { inst, inv: rec.inv });
                    pool.recycle_finish(rec);
                } else {
                    events.schedule(at, Event::Finish { inst, rec });
                }
            }
        }
    }

    /// Probe the warm-pool churn a placement caused: the idle reaper and
    /// the lifetime recycler both run inside `place_deploy`, so their
    /// effect shows as counter deltas around the call.
    fn note_placement_churn(&mut self, now: SimTime, expired0: u64, recycled0: u64) {
        if self.platform.expired > expired0 {
            self.obs.emit(
                now,
                ProbeEvent::IdleExpired { count: self.platform.expired - expired0 },
            );
        }
        if self.platform.recycled > recycled0 {
            self.obs.emit(
                now,
                ProbeEvent::Recycled { count: self.platform.recycled - recycled0 },
            );
        }
    }

    /// Probe and settle one admission outcome: sheds are terminal (the
    /// queue already counted them) and dispatch only runs when the
    /// arrival actually queued.
    fn settle_admission(&mut self, events: &mut EventQueue<Event>, now: SimTime, adm: Admission) {
        self.obs
            .emit(now, ProbeEvent::Submitted { inv: adm.inv.id, attempt: adm.inv.retries });
        if let Some(victim) = adm.evicted {
            self.obs.emit(now, ProbeEvent::Shed { inv: victim.id });
            self.revive_vu(events, now, victim.vu);
        }
        if adm.shed_new {
            self.obs.emit(now, ProbeEvent::Shed { inv: adm.inv.id });
            self.revive_vu(events, now, adm.inv.vu);
        } else {
            events.schedule(now, Event::Dispatch);
        }
    }

    /// Closed-loop VUs block on their one outstanding request; when it
    /// leaves the system without completing (terminal failure or shed),
    /// the VU behaves like a user seeing an error: think, then resubmit.
    /// Open-loop and trace arrivals drive themselves.
    fn revive_vu(&self, events: &mut EventQueue<Event>, now: SimTime, vu: u32) {
        if self.cfg.open_loop_rate_rps.is_none() && self.cfg.replay.is_none() {
            events.schedule(self.cfg.vus.next_submit_at(now), Event::Submit { vu });
        }
    }

    /// An in-flight attempt was killed by the fault plane (node death,
    /// spawn failure, or injected mid-flight crash): count it and put the
    /// invocation back through the retry gate. Never billed — the tenant
    /// doesn't pay for infrastructure failure.
    fn settle_fault_casualty(
        &mut self,
        events: &mut EventQueue<Event>,
        now: SimTime,
        inv: Invocation,
    ) {
        self.result.inflight_faults += 1;
        match adjudicate_requeue(
            &self.cfg.retry,
            &mut self.queue,
            &mut self.result,
            &mut self.obs,
            0,
            &mut self.rng_fault,
            now,
            inv,
        ) {
            Some(delay_ms) => {
                events.schedule_in_ms(self.minos.requeue_overhead_ms + delay_ms, Event::Dispatch);
            }
            None => self.revive_vu(events, now, inv.vu),
        }
    }

    /// Execute every planned node death due now: kill the machine and its
    /// resident instances (their in-flight events settle as fault
    /// casualties when they fire), then spawn a replacement unless the
    /// spawn fault eats it. Reschedules itself for the next death.
    fn process_churn(&mut self, now: SimTime, events: &mut EventQueue<Event>) {
        let Some(churn) = self.churn.as_mut() else { return };
        let mut due = std::mem::take(&mut churn.due);
        churn.plan.pop_due(now, &mut due);
        for death in due.drain(..) {
            let victim = churn.nodes[death.ordinal as usize];
            let mut victims = std::mem::take(&mut churn.victims);
            // `fail_node` refuses stale ids and the last machine standing
            // (a fleet of zero nodes could never serve the rest of the
            // queue) — a refused death is simply dropped.
            if self.platform.fail_node(victim, &mut victims) {
                self.obs
                    .emit(now, ProbeEvent::NodeFault { victims: victims.len() as u64 });
                if self.obs.is_on() {
                    for v in &victims {
                        self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: v.0 });
                    }
                }
                if self.cfg.fault.spawn_fail_p > 0.0
                    && self.rng_fault.f64() < self.cfg.fault.spawn_fail_p
                {
                    self.obs.emit(now, ProbeEvent::SpawnFailed);
                    self.result.spawn_failed += 1;
                } else {
                    let fresh = self.platform.spawn_node(self.cfg.day, &mut self.rng_fault, now);
                    let ordinal = churn.plan.add_node(now, &mut self.rng_fault);
                    debug_assert_eq!(ordinal as usize, churn.nodes.len());
                    churn.nodes.push(fresh);
                }
            }
            churn.victims = victims;
        }
        churn.due = due;
        if let Some(at) = churn.plan.next_at() {
            events.schedule(at.max(now), Event::NodeFault);
        }
    }
}

impl World for MinosWorld<'_> {
    type Event = Event;

    fn handle(
        &mut self,
        now: SimTime,
        ev: Event,
        events: &mut EventQueue<Event>,
    ) -> Result<()> {
        match ev {
            Event::Arrival => {
                if self.cfg.vus.may_submit(now) {
                    let vu = self.arrival_rr % self.cfg.vus.n_vus.max(1);
                    self.arrival_rr = self.arrival_rr.wrapping_add(1);
                    let adm = self.queue.submit(vu, now);
                    self.settle_admission(events, now, adm);
                    let rate = self.cfg.open_loop_rate_rps.expect("arrival without rate");
                    let gap_ms = self.rng_workload.exponential(rate) * 1_000.0;
                    events.schedule_in_ms(gap_ms, Event::Arrival);
                }
            }

            Event::TraceArrival { idx } => {
                let schedule =
                    self.cfg.replay.as_ref().expect("trace arrival without schedule");
                let (_, payload_scale) = schedule.arrivals[idx];
                // Round-robin the VU id: it only selects the dataset for
                // real execution; the trace, not a think loop, drives load.
                let vu = self.arrival_rr % self.cfg.vus.n_vus.max(1);
                self.arrival_rr = self.arrival_rr.wrapping_add(1);
                let t_next = schedule.arrivals.get(idx + 1).map(|&(t, _)| t);
                let adm = self.queue.submit_scaled(vu, payload_scale, now);
                self.settle_admission(events, now, adm);
                if let Some(t_next) = t_next {
                    events.schedule(t_next, Event::TraceArrival { idx: idx + 1 });
                }
            }

            Event::Submit { vu } => {
                if self.cfg.vus.may_submit(now) {
                    let adm = self.queue.submit(vu, now);
                    self.settle_admission(events, now, adm);
                }
            }

            Event::Dispatch => {
                let Some(inv) = self.queue.take() else { return Ok(()) };
                let (expired0, recycled0) = (self.platform.expired, self.platform.recycled);
                let placement = self.platform.place_deploy(DeployId::SOLO, now);
                self.note_placement_churn(now, expired0, recycled0);
                match placement {
                    Placement::Warm(inst) => {
                        self.obs.emit(now, ProbeEvent::WarmHit { inst: inst.0 });
                        self.start_invocation(events, now, inst, inv, false);
                    }
                    Placement::Cold { id, ready_at } => {
                        self.obs.emit(now, ProbeEvent::InstanceSpawned { inst: id.0 });
                        self.rec.note_cold_spawn(id.0, ready_at.ms_since(now));
                        events.schedule(ready_at, Event::ColdReady { inst: id, inv });
                    }
                    Placement::Saturated => {
                        // Platform quota: park the invocation at the queue
                        // head and retry after the (configurable)
                        // saturation delay — unless its deadline already
                        // passed, in which case it fails terminally.
                        self.obs.emit(now, ProbeEvent::Saturated);
                        if self.cfg.retry.past_deadline(inv.submitted_at, now) {
                            self.obs.emit(
                                now,
                                ProbeEvent::RequestFailed {
                                    inv: inv.id,
                                    attempt: inv.retries,
                                    reason: FailReason::DeadlineExceeded,
                                },
                            );
                            self.queue.fail(&inv);
                            self.result.failed_deadline += 1;
                            self.revive_vu(events, now, inv.vu);
                            // The quota may still fit a fresher request.
                            events.schedule(now, Event::Dispatch);
                        } else {
                            self.queue.untake(inv);
                            events.schedule_in_ms(
                                self.cfg.retry.saturated_delay_ms,
                                Event::Dispatch,
                            );
                        }
                    }
                }
            }

            Event::ColdReady { inst, inv } => {
                // The node died while this cold start was booting.
                if !self.platform.scheduler.is_current(inst) {
                    self.settle_fault_casualty(events, now, inv);
                    return Ok(());
                }
                self.platform.cold_start_ready(inst);
                // Spawn fault: the instance dies before it ever serves.
                if self.cfg.fault.spawn_fail_p > 0.0
                    && self.rng_fault.f64() < self.cfg.fault.spawn_fail_p
                {
                    if self.obs.is_on() {
                        self.obs.emit(now, ProbeEvent::SpawnFailed);
                        self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: inst.0 });
                    }
                    self.result.spawn_failed += 1;
                    self.platform.crash(inst);
                    self.settle_fault_casualty(events, now, inv);
                    return Ok(());
                }
                self.start_invocation(events, now, inst, inv, true);
            }

            Event::CrashRequeue { inst, crash } => {
                // A node fault beat the scheduled termination: the attempt
                // is a plain fault casualty — nothing billed or terminated.
                if !self.platform.scheduler.is_current(inst) {
                    let inv = crash.inv;
                    self.pool.recycle_crash(crash);
                    self.settle_fault_casualty(events, now, inv);
                    return Ok(());
                }
                if self.obs.is_on() {
                    self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: inst.0 });
                    self.obs.emit(
                        now,
                        ProbeEvent::Terminated {
                            inv: crash.inv.id,
                            attempt: crash.inv.retries,
                            bench_ms: crash.bench_ms,
                        },
                    );
                }
                self.platform.crash(inst);
                settle_crash(&self.cfg.billing, &mut self.result, now, &crash);
                let inv = crash.inv;
                self.pool.recycle_crash(crash);
                match adjudicate_requeue(
                    &self.cfg.retry,
                    &mut self.queue,
                    &mut self.result,
                    &mut self.obs,
                    0,
                    &mut self.rng_fault,
                    now,
                    inv,
                ) {
                    Some(delay_ms) => {
                        events.schedule_in_ms(
                            self.minos.requeue_overhead_ms + delay_ms,
                            Event::Dispatch,
                        );
                    }
                    None => self.revive_vu(events, now, inv.vu),
                }
            }

            Event::Finish { inst, rec } => {
                // The node died mid-execution: the completion never
                // happened — settle as a fault casualty instead.
                if !self.platform.scheduler.is_current(inst) {
                    let inv = rec.inv;
                    self.pool.recycle_finish(rec);
                    self.settle_fault_casualty(events, now, inv);
                    return Ok(());
                }
                self.platform.release(inst, now);
                // Pushed policy updates arrive between requests (§IV).
                self.policy.on_request_complete();
                if self.obs.is_on() {
                    self.obs.emit(
                        now,
                        ProbeEvent::Finished {
                            inv: rec.inv.id,
                            attempt: rec.inv.retries,
                            cold: rec.cold,
                            e2e_ms: now.ms_since(rec.inv.submitted_at),
                        },
                    );
                    self.obs.note_policy(
                        now,
                        self.policy.published_threshold(),
                        self.policy.pushes(),
                    );
                }
                let prediction =
                    match (self.runtime, self.datasets.get(rec.inv.vu as usize)) {
                        (Some(rt), Some(data)) => {
                            let out = rt.exec_linreg(&data.x, &data.y, &data.x_next)?;
                            verify_against_oracle(data, &out);
                            Some(out.prediction)
                        }
                        _ => None,
                    };
                settle_finish(
                    &self.cfg.billing,
                    &mut self.result,
                    &mut self.queue,
                    now,
                    &rec,
                    prediction,
                );
                self.pool.recycle_finish(rec);
                // Closed loop: the VU thinks, then submits again. (Open-
                // loop and trace-replay arrivals schedule themselves.)
                if self.cfg.open_loop_rate_rps.is_none() && self.cfg.replay.is_none() {
                    let next = self.cfg.vus.next_submit_at(now);
                    events.schedule(next, Event::Submit { vu: rec.inv.vu });
                }
            }

            Event::FaultCrash { inst, inv } => {
                // Injected mid-flight fault. A node fault may have razed
                // the instance first — either way the attempt is dead and
                // the invocation goes back through the retry gate.
                if self.platform.scheduler.is_current(inst) {
                    self.obs.emit(now, ProbeEvent::InstanceCrashed { inst: inst.0 });
                    self.platform.crash(inst);
                }
                self.settle_fault_casualty(events, now, inv);
            }

            Event::NodeFault => self.process_churn(now, events),
        }
        Ok(())
    }

    fn observe(&mut self, now: SimTime) {
        if !self.obs.is_on() {
            return;
        }
        self.obs.note_drift(now, self.platform.nodes().drift_epochs());
        if let Some(at) = self.obs.gauge_due(now) {
            let sample = GaugeSample {
                at,
                queue_depth: self.queue.len() as u64,
                fleet: self.platform.fleet_gauges(),
                completed: self.result.successful(),
                terminations: self.result.terminations,
                cost_usd: self.result.total_cost_usd(),
                failed: self.result.failed(),
                shed: self.queue.shed,
                node_faults: self.platform.node_faults,
            };
            self.obs.record_gauge(sample);
        }
    }
}

/// Cross-check a real PJRT execution against the Rust OLS oracle.
pub(crate) fn verify_against_oracle(
    data: &weather::WeatherData,
    out: &crate::runtime::engine::LinregOutput,
) {
    let theta = crate::workload::oracle::ols_fit(
        &data.x,
        &data.y,
        weather::N_DAYS,
        weather::N_FEATURES,
    );
    let want = crate::workload::oracle::predict(&theta, &data.x_next);
    let got = out.prediction as f64;
    assert!(
        (got - want).abs() < 0.05 * want.abs().max(1.0),
        "PJRT prediction {got} diverges from oracle {want}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_enum_stays_small() {
        // The queue copies every event on push and pop; the per-invocation
        // payloads are boxed precisely to keep this at or under 64 bytes
        // (it was 104 with FinishRecord carried inline).
        assert!(
            std::mem::size_of::<Event>() <= 64,
            "hot Event enum grew to {} bytes",
            std::mem::size_of::<Event>()
        );
        // The full queue entry (time + seq + event) must stay within 80
        // bytes so the two-tier queue's bucket `Vec`s stay cache-friendly.
        assert!(
            crate::sim::event::entry_bytes::<Event>() <= 80,
            "queue entry grew to {} bytes",
            crate::sim::event::entry_bytes::<Event>()
        );
    }

    #[test]
    fn finish_record_maps_fields() {
        let inv = Invocation {
            id: 9,
            vu: 2,
            submitted_at: SimTime::from_ms(5.0),
            retries: 1,
            forced_pass: true,
            payload_scale: 1.0,
        };
        let rec = FinishRecord {
            inv,
            cold: true,
            forced: true,
            prepare_ms: 100.0,
            analysis_ms: 200.0,
            exec_ms: 350.0,
            bench_ms: None,
        };
        let r = finish_record(&rec, SimTime::from_ms(400.0), None);
        assert_eq!(r.inv_id, 9);
        assert_eq!(r.attempts, 2);
        assert!(r.cold && r.forced);
        assert_eq!(r.completed_at, SimTime::from_ms(400.0));
        assert!((r.latency_ms() - 395.0).abs() < 1e-9);
    }

    #[test]
    fn record_pool_recycles_boxes() {
        let inv = Invocation {
            id: 1,
            vu: 0,
            submitted_at: SimTime::ZERO,
            retries: 0,
            forced_pass: false,
            payload_scale: 1.0,
        };
        let mut pool = RecordPool::new();
        let a = pool.alloc_crash(CrashRecord { inv, bench_ms: 10.0 });
        let addr = &*a as *const CrashRecord as usize;
        pool.recycle_crash(a);
        assert_eq!(pool.pooled(), (0, 1));
        // The next allocation reuses the same box, re-initialized.
        let b = pool.alloc_crash(CrashRecord { inv, bench_ms: 20.0 });
        assert_eq!(&*b as *const CrashRecord as usize, addr);
        assert_eq!(b.bench_ms, 20.0);
        assert_eq!(pool.pooled(), (0, 0));
    }

    #[test]
    fn baseline_build_ignores_the_spec() {
        // A disabled MinosConfig must yield the baseline policy whatever
        // the experiment-level spec says — that is what keeps the paired
        // baseline arm identical under any --policy.
        let p = build_policy(
            crate::policy::PolicySpec::Budgeted { max_rate: 0.5 },
            &MinosConfig::baseline(),
            60.0,
        );
        assert!(!p.benchmarks());
    }
}

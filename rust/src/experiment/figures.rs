//! Figure emitters: compute exactly the rows/series the paper's Figs. 4–7
//! report, from a week of paired outcomes.
//!
//! Each emitter returns a typed row set plus a [`crate::util::csvio::Csv`]
//! rendering; the bench binaries print them and EXPERIMENTS.md records the
//! paper-vs-measured comparison.

use crate::stats::descriptive::{mean, median};
use crate::util::csvio::Csv;

use super::runner::PairedOutcome;

/// Fig. 4 — per-day linear-regression (analysis) duration.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub day: u32,
    pub baseline_median_ms: f64,
    pub minos_median_ms: f64,
    pub baseline_mean_ms: f64,
    pub minos_mean_ms: f64,
    pub median_improvement_pct: f64,
    pub mean_improvement_pct: f64,
}

pub fn fig4(outcomes: &[PairedOutcome]) -> (Vec<Fig4Row>, Csv) {
    let rows: Vec<Fig4Row> = outcomes
        .iter()
        .map(|o| {
            let b = o.baseline.analysis_durations();
            let m = o.minos.analysis_durations();
            let (bm, mm) = (median(&b), median(&m));
            let (ba, ma) = (mean(&b), mean(&m));
            Fig4Row {
                day: o.day + 1,
                baseline_median_ms: bm,
                minos_median_ms: mm,
                baseline_mean_ms: ba,
                minos_mean_ms: ma,
                median_improvement_pct: (bm - mm) / bm * 100.0,
                mean_improvement_pct: (ba - ma) / ba * 100.0,
            }
        })
        .collect();
    let mut csv = Csv::new(&[
        "day",
        "baseline_median_ms",
        "minos_median_ms",
        "baseline_mean_ms",
        "minos_mean_ms",
        "median_improvement_pct",
        "mean_improvement_pct",
    ]);
    for r in &rows {
        csv.push(vec![
            r.day.to_string(),
            format!("{:.1}", r.baseline_median_ms),
            format!("{:.1}", r.minos_median_ms),
            format!("{:.1}", r.baseline_mean_ms),
            format!("{:.1}", r.minos_mean_ms),
            format!("{:.2}", r.median_improvement_pct),
            format!("{:.2}", r.mean_improvement_pct),
        ]);
    }
    (rows, csv)
}

/// Overall mean analysis improvement across the week (paper: 7.8 %).
pub fn fig4_overall_improvement_pct(outcomes: &[PairedOutcome]) -> f64 {
    let b: Vec<f64> =
        outcomes.iter().flat_map(|o| o.baseline.analysis_durations()).collect();
    let m: Vec<f64> = outcomes.iter().flat_map(|o| o.minos.analysis_durations()).collect();
    (mean(&b) - mean(&m)) / mean(&b) * 100.0
}

/// Fig. 5 — successful requests per day.
#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub day: u32,
    pub baseline_successful: u64,
    pub minos_successful: u64,
    pub improvement_pct: f64,
}

pub fn fig5(outcomes: &[PairedOutcome]) -> (Vec<Fig5Row>, Csv) {
    let rows: Vec<Fig5Row> = outcomes
        .iter()
        .map(|o| Fig5Row {
            day: o.day + 1,
            baseline_successful: o.baseline.successful(),
            minos_successful: o.minos.successful(),
            improvement_pct: o.successful_requests_improvement_pct(),
        })
        .collect();
    let mut csv = Csv::new(&["day", "baseline_successful", "minos_successful", "improvement_pct"]);
    for r in &rows {
        csv.push(vec![
            r.day.to_string(),
            r.baseline_successful.to_string(),
            r.minos_successful.to_string(),
            format!("{:.2}", r.improvement_pct),
        ]);
    }
    (rows, csv)
}

/// Overall extra successful requests across the week (paper: +2.3 %).
pub fn fig5_overall_improvement_pct(outcomes: &[PairedOutcome]) -> f64 {
    let b: u64 = outcomes.iter().map(|o| o.baseline.successful()).sum();
    let m: u64 = outcomes.iter().map(|o| o.minos.successful()).sum();
    (m as f64 - b as f64) / b as f64 * 100.0
}

/// Fig. 6 — average total cost per million successful requests per day.
#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub day: u32,
    pub baseline_usd_per_million: f64,
    pub minos_usd_per_million: f64,
    pub saving_pct: f64,
}

pub fn fig6(outcomes: &[PairedOutcome]) -> (Vec<Fig6Row>, Csv) {
    let rows: Vec<Fig6Row> = outcomes
        .iter()
        .map(|o| Fig6Row {
            day: o.day + 1,
            baseline_usd_per_million: o.baseline.cost_per_million_usd(),
            minos_usd_per_million: o.minos.cost_per_million_usd(),
            saving_pct: o.cost_saving_pct(),
        })
        .collect();
    let mut csv =
        Csv::new(&["day", "baseline_usd_per_million", "minos_usd_per_million", "saving_pct"]);
    for r in &rows {
        csv.push(vec![
            r.day.to_string(),
            format!("{:.3}", r.baseline_usd_per_million),
            format!("{:.3}", r.minos_usd_per_million),
            format!("{:.2}", r.saving_pct),
        ]);
    }
    (rows, csv)
}

/// Overall cost saving across the week (paper: 0.9 %).
pub fn fig6_overall_saving_pct(outcomes: &[PairedOutcome]) -> f64 {
    let b_cost: f64 = outcomes.iter().map(|o| o.baseline.total_cost_usd()).sum();
    let b_n: u64 = outcomes.iter().map(|o| o.baseline.successful()).sum();
    let m_cost: f64 = outcomes.iter().map(|o| o.minos.total_cost_usd()).sum();
    let m_n: u64 = outcomes.iter().map(|o| o.minos.successful()).sum();
    let b = b_cost / b_n as f64;
    let m = m_cost / m_n as f64;
    (b - m) / b * 100.0
}

/// Fig. 7 — running average cost per million successful requests over the
/// experiment duration, plus the crossover statistics the paper quotes.
#[derive(Debug, Clone)]
pub struct Fig7Series {
    /// (t_seconds, baseline $/M, minos $/M) on a uniform grid.
    pub points: Vec<(f64, f64, f64)>,
    /// First time after which Minos stays cheaper on >50 % of sampled
    /// points so far (paper: 670 s).
    pub majority_cheaper_after_s: Option<f64>,
    /// Fraction of the horizon where Minos is cheaper (paper: 76 %).
    pub fraction_cheaper: f64,
}

pub fn fig7(outcome: &PairedOutcome, step_s: f64, horizon_s: f64) -> (Fig7Series, Csv) {
    let b = outcome.baseline.cost_series(step_s, horizon_s);
    let m = outcome.minos.cost_series(step_s, horizon_s);
    // Align on the common time grid (both series start once the first
    // request completes; join on t).
    let mut points = Vec::new();
    let mut bi = 0usize;
    for &(t, mv) in &m {
        while bi < b.len() && b[bi].0 < t - 1e-9 {
            bi += 1;
        }
        if bi < b.len() && (b[bi].0 - t).abs() < 1e-9 {
            points.push((t, b[bi].1, mv));
        }
    }
    let cheaper_flags: Vec<bool> = points.iter().map(|&(_, bv, mv)| mv < bv).collect();
    let fraction_cheaper = if cheaper_flags.is_empty() {
        0.0
    } else {
        cheaper_flags.iter().filter(|&&c| c).count() as f64 / cheaper_flags.len() as f64
    };
    // Paper's "after 670 s Minos was cheaper for more than 50 % of time":
    // earliest t where the running majority of sampled points is cheaper.
    let mut majority_cheaper_after_s = None;
    let mut cheap = 0usize;
    for (i, &c) in cheaper_flags.iter().enumerate() {
        if c {
            cheap += 1;
        }
        if cheap * 2 > i + 1 {
            majority_cheaper_after_s = Some(points[i].0);
            break;
        }
    }
    let series = Fig7Series { points, majority_cheaper_after_s, fraction_cheaper };
    let mut csv = Csv::new(&["t_s", "baseline_usd_per_million", "minos_usd_per_million"]);
    for &(t, bv, mv) in &series.points {
        csv.push(vec![
            format!("{t:.0}"),
            format!("{bv:.3}"),
            format!("{mv:.3}"),
        ]);
    }
    (series, csv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::config::ExperimentConfig;
    use crate::experiment::runner::run_paired;

    fn outcomes() -> Vec<PairedOutcome> {
        (0..2)
            .map(|d| run_paired(&ExperimentConfig::smoke(d, 40 + d as u64), None).unwrap())
            .collect()
    }

    #[test]
    fn fig4_rows_consistent() {
        let o = outcomes();
        let (rows, csv) = fig4(&o);
        assert_eq!(rows.len(), 2);
        assert_eq!(csv.rows.len(), 2);
        for r in &rows {
            assert!(r.baseline_median_ms > 500.0);
            // improvement_pct consistent with the medians
            let recompute =
                (r.baseline_median_ms - r.minos_median_ms) / r.baseline_median_ms * 100.0;
            assert!((recompute - r.median_improvement_pct).abs() < 1e-9);
        }
    }

    #[test]
    fn fig5_counts_match_results() {
        let o = outcomes();
        let (rows, _) = fig5(&o);
        assert_eq!(rows[0].baseline_successful, o[0].baseline.successful());
        assert_eq!(rows[1].minos_successful, o[1].minos.successful());
    }

    #[test]
    fn fig6_in_plausible_cost_range() {
        let o = outcomes();
        let (rows, _) = fig6(&o);
        for r in &rows {
            assert!(
                (8.0..25.0).contains(&r.baseline_usd_per_million),
                "cost {} out of range",
                r.baseline_usd_per_million
            );
        }
    }

    #[test]
    fn fig7_series_aligned_and_bounded() {
        let o = &outcomes()[0];
        let (series, csv) = fig7(o, 10.0, 120.0);
        assert!(!series.points.is_empty());
        assert_eq!(csv.rows.len(), series.points.len());
        assert!((0.0..=1.0).contains(&series.fraction_cheaper));
        for w in series.points.windows(2) {
            assert!(w[1].0 > w[0].0, "time grid must increase");
        }
    }

    #[test]
    fn overall_aggregates_finite() {
        let o = outcomes();
        assert!(fig4_overall_improvement_pct(&o).is_finite());
        assert!(fig5_overall_improvement_pct(&o).is_finite());
        assert!(fig6_overall_saving_pct(&o).is_finite());
    }
}

//! The experiment runner: the discrete-event main loop that glues virtual
//! users → invocation queue → platform placement → Minos cold-start gate →
//! function execution → billing (paper Figs. 1 and 2).
//!
//! Timeline of one invocation attempt on an instance (times relative to
//! when the instance starts serving it):
//!
//! ```text
//! cold + Minos:   [ prepare (download) ───────────────┐
//!                 [ benchmark ──┬ judge               │
//!                               ├ fail: re-queue + crash (billed: bench)
//!                               └ pass ▼              ▼
//!                                      [ analysis ][ overhead ]  (billed:
//!                                  max(prepare, bench) + analysis + ovh)
//! cold baseline / forced / warm:
//!                 [ prepare ][ analysis ][ overhead ]
//! ```
//!
//! When a [`Runtime`] is supplied, every completed invocation *really*
//! executes the weather-regression HLO artifact through PJRT and the
//! prediction is verified against the Rust OLS oracle — the simulator
//! decides *when* things happen, the artifacts decide *what* is computed.

use anyhow::Result;

use crate::coordinator::lifecycle::{decide_cold_start, ColdStartDecision};
use crate::coordinator::online::OnlineThreshold;
use crate::coordinator::pretest::PretestReport;
use crate::coordinator::queue::{Invocation, InvocationQueue};
use crate::coordinator::MinosConfig;
use crate::platform::{FaasPlatform, InstanceId, Placement};
use crate::runtime::Runtime;
use crate::sim::{EventQueue, SimTime};
use crate::trace::{FunctionId, FunctionRegistry, Trace};
use crate::util::prng::Rng;
use crate::workload::weather;

use super::config::ExperimentConfig;
use super::metrics::{CostEvent, InvocationRecord, RunResult};

/// Domain events of the simulation.
#[derive(Debug)]
enum Event {
    /// Open-loop mode: a Poisson arrival (schedules its own successor).
    Arrival,
    /// Trace-replay mode: the `idx`-th scheduled arrival (schedules its
    /// successor at the next trace timestamp — no allocation per event).
    TraceArrival { idx: usize },
    /// A virtual user submits a new request.
    Submit { vu: u32 },
    /// Try to place the queue head.
    Dispatch,
    /// A cold start finished; the instance begins serving `inv`.
    ColdReady { inst: InstanceId, inv: Invocation },
    /// A Minos-terminated instance crashes after its benchmark; the
    /// invocation re-enters the queue.
    CrashRequeue { inst: InstanceId, inv: Invocation, bench_ms: f64 },
    /// An invocation completed successfully.
    Finish { inst: InstanceId, inv: Invocation, rec: PendingRecord },
}

/// Record fields computed at invocation start, finalized at completion.
#[derive(Debug, Clone)]
struct PendingRecord {
    cold: bool,
    forced: bool,
    prepare_ms: f64,
    analysis_ms: f64,
    exec_ms: f64,
    bench_ms: Option<f64>,
}

/// Run one condition (Minos or baseline) for one day.
///
/// `salt` separates the placement lottery between pre-test and main runs;
/// paired conditions use the same salt. `runtime` enables real artifact
/// execution per completed invocation.
pub fn run_single(
    cfg: &ExperimentConfig,
    minos: &MinosConfig,
    salt: u64,
    bench_warm: bool,
    runtime: Option<&Runtime>,
) -> Result<RunResult> {
    let mut platform =
        FaasPlatform::new_salted(cfg.platform.clone(), cfg.day, cfg.seed, salt);
    let mut queue = InvocationQueue::new();
    let mut events: EventQueue<Event> = EventQueue::new();
    let mut result = RunResult {
        threshold_ms: minos.elysium_threshold_ms,
        ..Default::default()
    };
    let root = Rng::new(cfg.seed ^ 0x9E3779B97F4A7C15);
    let mut rng_workload = root.fork(7_000 + cfg.day as u64 + salt * 31);
    let mut online = cfg.online_update_every.map(|every| {
        OnlineThreshold::new(cfg.elysium_percentile, minos.elysium_threshold_ms, every)
    });
    let mut live_minos = minos.clone();

    // Per-VU weather dataset (location) for real execution.
    let datasets: Vec<weather::WeatherData> = if runtime.is_some() {
        (0..cfg.vus.n_vus)
            .map(|vu| weather::generate(cfg.seed ^ (vu as u64) << 32))
            .collect()
    } else {
        Vec::new()
    };

    if let Some(schedule) = &cfg.replay {
        // Trace replay: arrivals happen exactly when the trace says.
        if let Some(&(t0, _)) = schedule.arrivals.first() {
            events.schedule(t0, Event::TraceArrival { idx: 0 });
        }
    } else {
        match cfg.open_loop_rate_rps {
            // Open loop: one Poisson arrival process drives the queue.
            Some(rate) => {
                assert!(rate > 0.0, "open-loop rate must be positive");
                events.schedule(SimTime::ZERO, Event::Arrival);
            }
            // Closed loop (the paper's load generator): all VUs submit at t=0.
            None => {
                for vu in 0..cfg.vus.n_vus {
                    events.schedule(SimTime::ZERO, Event::Submit { vu });
                }
            }
        }
    }
    let mut arrival_rr: u32 = 0; // round-robin dataset assignment

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival => {
                if cfg.vus.may_submit(now) {
                    let vu = arrival_rr % cfg.vus.n_vus.max(1);
                    arrival_rr = arrival_rr.wrapping_add(1);
                    queue.submit(vu, now);
                    events.schedule(now, Event::Dispatch);
                    let rate = cfg.open_loop_rate_rps.expect("arrival without rate");
                    let gap_ms = rng_workload.exponential(rate) * 1_000.0;
                    events.schedule_in_ms(gap_ms, Event::Arrival);
                }
            }

            Event::TraceArrival { idx } => {
                let schedule = cfg.replay.as_ref().expect("trace arrival without schedule");
                let (_, payload_scale) = schedule.arrivals[idx];
                // Round-robin the VU id: it only selects the dataset for
                // real execution; the trace, not a think loop, drives load.
                let vu = arrival_rr % cfg.vus.n_vus.max(1);
                arrival_rr = arrival_rr.wrapping_add(1);
                queue.submit_scaled(vu, payload_scale, now);
                events.schedule(now, Event::Dispatch);
                if let Some(&(t_next, _)) = schedule.arrivals.get(idx + 1) {
                    events.schedule(t_next, Event::TraceArrival { idx: idx + 1 });
                }
            }

            Event::Submit { vu } => {
                if cfg.vus.may_submit(now) {
                    queue.submit(vu, now);
                    events.schedule(now, Event::Dispatch);
                }
            }

            Event::Dispatch => {
                let Some(inv) = queue.take() else { continue };
                match platform.place(now) {
                    Placement::Warm(inst) => {
                        start_invocation(
                            StartCtx {
                                cfg,
                                minos: &live_minos,
                                platform: &mut platform,
                                events: &mut events,
                                result: &mut result,
                                queue: &mut queue,
                                rng: &mut rng_workload,
                                online: &mut online,
                                bench_warm,
                            },
                            now,
                            inst,
                            inv,
                            false,
                        );
                    }
                    Placement::Cold { id, ready_at } => {
                        events.schedule(ready_at, Event::ColdReady { inst: id, inv });
                    }
                    Placement::Saturated => {
                        // Platform quota: put the invocation back at the
                        // queue head and retry shortly.
                        queue.untake(inv);
                        events.schedule_in_ms(100.0, Event::Dispatch);
                    }
                }
            }

            Event::ColdReady { inst, inv } => {
                platform.cold_start_ready(inst);
                start_invocation(
                    StartCtx {
                        cfg,
                        minos: &live_minos,
                        platform: &mut platform,
                        events: &mut events,
                        result: &mut result,
                        queue: &mut queue,
                        rng: &mut rng_workload,
                        online: &mut online,
                        bench_warm,
                    },
                    now,
                    inst,
                    inv,
                    true,
                );
            }

            Event::CrashRequeue { inst, inv, bench_ms } => {
                // Bill the terminated attempt: the instance consumed the
                // benchmark duration before crashing (Fig. 3's d_term).
                result.cost_events.push(CostEvent {
                    at: now,
                    usd: cfg.billing.invocation_cost_usd(bench_ms),
                    terminated: true,
                });
                result.terminations += 1;
                platform.crash(inst);
                queue.requeue(inv);
                events.schedule_in_ms(live_minos.requeue_overhead_ms, Event::Dispatch);
            }

            Event::Finish { inst, inv, rec } => {
                platform.release(inst, now);
                queue.complete(&inv);
                result.cost_events.push(CostEvent {
                    at: now,
                    usd: cfg.billing.invocation_cost_usd(rec.exec_ms),
                    terminated: false,
                });
                // Online threshold updates arrive between requests (§IV).
                if let Some(ot) = online.as_mut() {
                    live_minos.elysium_threshold_ms = ot.published();
                }
                let prediction = match (runtime, datasets.get(inv.vu as usize)) {
                    (Some(rt), Some(data)) => {
                        let out = rt.exec_linreg(&data.x, &data.y, &data.x_next)?;
                        verify_against_oracle(data, &out);
                        Some(out.prediction)
                    }
                    _ => None,
                };
                result.records.push(InvocationRecord {
                    inv_id: inv.id,
                    vu: inv.vu,
                    submitted_at: inv.submitted_at,
                    completed_at: now,
                    attempts: inv.retries + 1,
                    forced: rec.forced,
                    cold: rec.cold,
                    prepare_ms: rec.prepare_ms,
                    analysis_ms: rec.analysis_ms,
                    exec_ms: rec.exec_ms,
                    bench_ms: rec.bench_ms,
                    prediction,
                });
                // Closed loop: the VU thinks, then submits again. (Open-
                // loop and trace-replay arrivals schedule themselves.)
                if cfg.open_loop_rate_rps.is_none() && cfg.replay.is_none() {
                    let next = cfg.vus.next_submit_at(now);
                    events.schedule(next, Event::Submit { vu: inv.vu });
                }
            }
        }
    }

    debug_assert!(queue.conserved(), "invocation conservation violated");
    result.cold_starts = platform.cold_starts;
    result.warm_hits = platform.warm_hits;
    result.expired = platform.expired;
    result.recycled = platform.recycled;
    if let Some(ot) = online {
        result.online_pushes = ot.pushes;
    }
    Ok(result)
}

/// Borrow bundle for [`start_invocation`] (keeps the call sites readable).
struct StartCtx<'a> {
    cfg: &'a ExperimentConfig,
    minos: &'a MinosConfig,
    platform: &'a mut FaasPlatform,
    events: &'a mut EventQueue<Event>,
    result: &'a mut RunResult,
    queue: &'a mut InvocationQueue,
    rng: &'a mut Rng,
    online: &'a mut Option<OnlineThreshold>,
    bench_warm: bool,
}

/// An instance begins serving an invocation (paper Fig. 2's flow).
fn start_invocation(
    ctx: StartCtx<'_>,
    now: SimTime,
    inst: InstanceId,
    mut inv: Invocation,
    cold: bool,
) {
    let StartCtx { cfg, minos, platform, events, result, queue, rng, online, bench_warm } =
        ctx;
    let perf = platform.perf_factor(inst, now);
    let noise = platform.invocation_noise();
    let phases = cfg.function.sample_scaled(perf, noise, inv.payload_scale, rng);

    if cold {
        let draw = rng.f64();
        let decision = decide_cold_start(minos, &inv, perf, draw, || {
            let b = minos.benchmark.duration_ms(perf, rng);
            result.bench_scores.push(b);
            if let Some(ot) = online.as_mut() {
                ot.report(b);
            }
            b
        });
        match decision {
            ColdStartDecision::TerminateAndRequeue { bench_ms } => {
                platform.scheduler.get_mut(inst).benchmark_score = Some(bench_ms);
                events.schedule(
                    now.plus_ms(bench_ms),
                    Event::CrashRequeue { inst, inv, bench_ms },
                );
                return;
            }
            ColdStartDecision::Run { forced, bench_ms } => {
                if forced {
                    inv.forced_pass = true;
                    result.forced_passes += 1;
                }
                if let Some(b) = bench_ms {
                    platform.scheduler.get_mut(inst).benchmark_score = Some(b);
                }
                // Analysis starts once both prepare and (any) benchmark are
                // done; the benchmark usually hides inside the download.
                let gate_ms = match bench_ms {
                    Some(b) => phases.prepare_ms.max(b),
                    None => phases.prepare_ms,
                };
                let exec_ms = gate_ms + phases.analysis_ms + phases.overhead_ms;
                events.schedule(
                    now.plus_ms(exec_ms),
                    Event::Finish {
                        inst,
                        inv,
                        rec: PendingRecord {
                            cold: true,
                            forced,
                            prepare_ms: phases.prepare_ms,
                            analysis_ms: phases.analysis_ms,
                            exec_ms,
                            bench_ms,
                        },
                    },
                );
                return;
            }
        }
    }

    // Warm path: no gate. During the pre-test (`bench_warm`) the benchmark
    // still runs — purely to collect scores; it never terminates a warm
    // instance and its duration hides inside prepare.
    let bench_ms = if bench_warm && minos.enabled {
        let b = minos.benchmark.duration_ms(perf, rng);
        result.bench_scores.push(b);
        if let Some(ot) = online.as_mut() {
            ot.report(b);
        }
        Some(b)
    } else {
        None
    };
    let gate_ms = match bench_ms {
        Some(b) => phases.prepare_ms.max(b),
        None => phases.prepare_ms,
    };
    let exec_ms = gate_ms + phases.analysis_ms + phases.overhead_ms;
    events.schedule(
        now.plus_ms(exec_ms),
        Event::Finish {
            inst,
            inv,
            rec: PendingRecord {
                cold: false,
                forced: false,
                prepare_ms: phases.prepare_ms,
                analysis_ms: phases.analysis_ms,
                exec_ms,
                bench_ms,
            },
        },
    );
    let _ = queue; // conservation counters only change on take/complete
}

/// Cross-check a real PJRT execution against the Rust OLS oracle.
fn verify_against_oracle(
    data: &weather::WeatherData,
    out: &crate::runtime::engine::LinregOutput,
) {
    let theta = crate::workload::oracle::ols_fit(
        &data.x,
        &data.y,
        weather::N_DAYS,
        weather::N_FEATURES,
    );
    let want = crate::workload::oracle::predict(&theta, &data.x_next);
    let got = out.prediction as f64;
    assert!(
        (got - want).abs() < 0.05 * want.abs().max(1.0),
        "PJRT prediction {got} diverges from oracle {want}"
    );
}

/// Pre-test (paper §II-B-a): a short run that benchmarks but never
/// terminates, then calibrates the threshold at the target percentile.
pub fn run_pretest(cfg: &ExperimentConfig, runtime: Option<&Runtime>) -> Result<PretestReport> {
    let mut pretest_cfg = cfg.clone();
    pretest_cfg.vus = cfg.pretest_vus.clone();
    // The pre-test is always the paper's closed-loop calibration workload,
    // even when the main run replays a trace.
    pretest_cfg.replay = None;
    let minos = MinosConfig {
        enabled: true,
        elysium_threshold_ms: f64::INFINITY,
        ..cfg.minos.clone()
    };
    let run = run_single(&pretest_cfg, &minos, 1, cfg.pretest_bench_warm, runtime)?;
    Ok(PretestReport::from_scores(run.bench_scores, cfg.elysium_percentile))
}

/// Both paper conditions on the identical platform draw.
#[derive(Debug)]
pub struct PairedOutcome {
    pub day: u32,
    pub pretest: PretestReport,
    pub minos: RunResult,
    pub baseline: RunResult,
}

impl PairedOutcome {
    /// Mean analysis-duration improvement, % (Fig. 4's headline measure).
    pub fn analysis_improvement_pct(&self) -> f64 {
        let b = crate::stats::mean(&self.baseline.analysis_durations());
        let m = crate::stats::mean(&self.minos.analysis_durations());
        (b - m) / b * 100.0
    }

    /// Median analysis-duration improvement, %.
    pub fn analysis_median_improvement_pct(&self) -> f64 {
        let b = crate::stats::median(&self.baseline.analysis_durations());
        let m = crate::stats::median(&self.minos.analysis_durations());
        (b - m) / b * 100.0
    }

    /// Extra successful requests, % (Fig. 5's measure).
    pub fn successful_requests_improvement_pct(&self) -> f64 {
        let b = self.baseline.successful() as f64;
        (self.minos.successful() as f64 - b) / b * 100.0
    }

    /// Cost-per-success saving, % (Fig. 6's measure; positive = cheaper).
    pub fn cost_saving_pct(&self) -> f64 {
        let b = self.baseline.cost_per_million_usd();
        (b - self.minos.cost_per_million_usd()) / b * 100.0
    }
}

/// Run pre-test + paired conditions for one configured day.
pub fn run_paired(cfg: &ExperimentConfig, runtime: Option<&Runtime>) -> Result<PairedOutcome> {
    let pretest = run_pretest(cfg, runtime)?;
    let minos_cfg = MinosConfig {
        enabled: true,
        elysium_threshold_ms: pretest.threshold_ms,
        ..cfg.minos.clone()
    };
    let baseline_cfg = MinosConfig { enabled: false, ..cfg.minos.clone() };
    // The paper deploys baseline and Minos as *separate functions* run at
    // the same time: same platform day, independent instance lotteries.
    let minos = run_single(cfg, &minos_cfg, 0, false, runtime)?;
    let baseline = run_single(cfg, &baseline_cfg, 2, false, runtime)?;
    Ok(PairedOutcome { day: cfg.day, pretest, minos, baseline })
}

/// The paper's full week: seven paired days.
pub fn run_week(
    base: &ExperimentConfig,
    days: u32,
    runtime: Option<&Runtime>,
) -> Result<Vec<PairedOutcome>> {
    (0..days)
        .map(|d| {
            let mut cfg = base.clone();
            cfg.day = d;
            cfg.seed = base.seed + d as u64;
            run_paired(&cfg, runtime)
        })
        .collect()
}

/// Per-function outcome of a trace replay.
#[derive(Debug)]
pub struct FunctionRunOutcome {
    pub id: FunctionId,
    pub name: String,
    /// Arrivals the trace addressed to this function.
    pub arrivals: usize,
    /// This function's own pre-test (its threshold calibration).
    pub pretest: PretestReport,
    pub result: RunResult,
}

/// Outcome of replaying a multi-function trace.
#[derive(Debug)]
pub struct TraceOutcome {
    pub per_function: Vec<FunctionRunOutcome>,
}

impl TraceOutcome {
    pub fn total_arrivals(&self) -> usize {
        self.per_function.iter().map(|f| f.arrivals).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.successful()).sum()
    }

    pub fn total_cost_usd(&self) -> f64 {
        self.per_function.iter().map(|f| f.result.total_cost_usd()).sum()
    }

    pub fn total_terminations(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.terminations).sum()
    }
}

/// Replay a multi-function trace: each function in the registry is its own
/// deployment (own warm pool, own instance lottery — exactly how FaaS
/// platforms isolate functions), pre-tested for its own elysium threshold,
/// then driven by the trace's arrivals for that function id. Functions the
/// trace never invokes are skipped.
pub fn run_trace(
    base: &ExperimentConfig,
    registry: &FunctionRegistry,
    trace: &Trace,
    runtime: Option<&Runtime>,
) -> Result<TraceOutcome> {
    // Refuse partial coverage: silently dropping records whose function id
    // has no profile would make the totals read as a complete replay.
    anyhow::ensure!(
        trace.n_functions() <= registry.len(),
        "trace addresses function ids up to {} but the registry defines only {} \
         profiles",
        trace.n_functions().saturating_sub(1),
        registry.len()
    );
    let mut per_function = Vec::new();
    // One O(N) pass splits the trace into per-function schedules.
    let mut schedules = trace.schedules(registry.len());
    for profile in registry.iter() {
        let schedule = std::mem::take(&mut schedules[profile.id.0 as usize]);
        if schedule.is_empty() {
            continue;
        }
        let mut cfg = base.clone();
        cfg.function = profile.spec.clone();
        cfg.minos = profile.minos.clone();
        cfg.elysium_percentile = profile.elysium_percentile;
        cfg.open_loop_rate_rps = None;
        cfg.replay = None;
        // Separate deployments get separate platform lotteries.
        cfg.seed = base
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(profile.id.0 as u64 + 1));
        // Calibrate this function's threshold (closed-loop pre-test,
        // paper §II-B-a), then replay its slice of the trace.
        let pretest = run_pretest(&cfg, runtime)?;
        let minos_cfg = MinosConfig {
            elysium_threshold_ms: pretest.threshold_ms,
            ..cfg.minos.clone()
        };
        let arrivals = schedule.len();
        cfg.replay = Some(std::sync::Arc::new(schedule));
        let result = run_single(&cfg, &minos_cfg, 0, false, runtime)?;
        per_function.push(FunctionRunOutcome {
            id: profile.id,
            name: profile.name.clone(),
            arrivals,
            pretest,
            result,
        });
    }
    Ok(TraceOutcome { per_function })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_run_completes_requests() {
        let cfg = ExperimentConfig::smoke(0, 7);
        let baseline = MinosConfig::baseline();
        let r = run_single(&cfg, &baseline, 0, false, None).unwrap();
        // 10 VUs × 120 s at ~4 s/request ⇒ ~300 requests.
        assert!(r.successful() > 150, "only {} successes", r.successful());
        assert!(r.terminations == 0, "baseline must not terminate");
        assert!(r.bench_scores.is_empty(), "baseline must not benchmark");
        assert_eq!(r.cold_starts as usize, 10);
    }

    #[test]
    fn minos_terminates_and_requeues() {
        let cfg = ExperimentConfig::smoke(1, 8); // high-sigma day
        let minos = MinosConfig {
            elysium_threshold_ms: 350.0, // ~median ⇒ ~half terminated
            ..MinosConfig::paper_default()
        };
        let r = run_single(&cfg, &minos, 0, false, None).unwrap();
        assert!(r.terminations > 0, "expected terminations");
        assert!(r.successful() > 100);
        // Terminated cost events exist and carry positive cost.
        assert!(r.cost_events.iter().any(|e| e.terminated && e.usd > 0.0));
    }

    #[test]
    fn pretest_calibrates_threshold() {
        let cfg = ExperimentConfig::paper_day(0);
        let report = run_pretest(&cfg, None).unwrap();
        assert!(report.scores_ms.len() >= 10, "{} scores", report.scores_ms.len());
        assert!(report.threshold_ms > 100.0 && report.threshold_ms < 1_500.0);
    }

    #[test]
    fn paired_runs_share_platform() {
        let cfg = ExperimentConfig::smoke(0, 9);
        let o = run_paired(&cfg, None).unwrap();
        // Conditions ran: both have successes; Minos has bench scores.
        assert!(o.minos.successful() > 0 && o.baseline.successful() > 0);
        assert!(!o.minos.bench_scores.is_empty());
        assert!(o.baseline.bench_scores.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig::smoke(2, 11);
        let m = MinosConfig::baseline();
        let a = run_single(&cfg, &m, 0, false, None).unwrap();
        let b = run_single(&cfg, &m, 0, false, None).unwrap();
        assert_eq!(a.successful(), b.successful());
        assert!((a.total_cost_usd() - b.total_cost_usd()).abs() < 1e-15);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.completed_at, y.completed_at);
        }
    }

    #[test]
    fn open_loop_poisson_arrivals() {
        let mut cfg = ExperimentConfig::smoke(0, 15);
        cfg.open_loop_rate_rps = Some(3.0);
        let baseline = MinosConfig::baseline();
        let r = run_single(&cfg, &baseline, 0, false, None).unwrap();
        // ~3 req/s over 120 s => ~360 arrivals; all must complete.
        let n = r.successful();
        assert!((250..=470).contains(&(n as i64)), "open-loop completions: {n}");
        // Open loop scales out past the closed-loop's 10 instances when
        // arrivals burst.
        assert!(r.cold_starts >= 10);
    }

    #[test]
    fn open_loop_minos_still_wins() {
        let mut cfg = ExperimentConfig::smoke(1, 16);
        cfg.vus.horizon = crate::sim::SimTime::from_secs(300.0);
        cfg.open_loop_rate_rps = Some(3.0);
        let o = run_paired(&cfg, None).unwrap();
        assert!(
            o.analysis_improvement_pct() > 0.0,
            "minos should win under open-loop arrivals: {:+.2}%",
            o.analysis_improvement_pct()
        );
    }

    #[test]
    fn retry_cap_bounds_attempts() {
        let cfg = ExperimentConfig::smoke(1, 13);
        let minos = MinosConfig {
            // Impossible threshold: every benchmark fails ⇒ every request
            // must be saved by the emergency exit after retry_cap tries.
            elysium_threshold_ms: 0.0,
            ..MinosConfig::paper_default()
        };
        let r = run_single(&cfg, &minos, 0, false, None).unwrap();
        assert!(r.successful() > 0, "emergency exit must save requests");
        // Every cold-path completion was saved by the emergency exit at
        // exactly the cap; warm re-uses of the forced-pass instances run
        // without a benchmark on the first attempt.
        let mut saw_forced = 0;
        for rec in &r.records {
            if rec.cold {
                assert_eq!(rec.attempts, minos.retry_cap + 1);
                assert!(rec.forced);
                saw_forced += 1;
            } else {
                assert_eq!(rec.attempts, 1);
                assert!(!rec.forced);
            }
            assert!(rec.attempts <= minos.retry_cap + 1, "cap exceeded");
        }
        assert!(saw_forced > 0, "no forced cold completions observed");
        assert_eq!(r.forced_passes, saw_forced);
    }

    #[test]
    fn replay_arrivals_follow_schedule() {
        let mut cfg = ExperimentConfig::smoke(0, 21);
        let schedule = crate::trace::ReplaySchedule::from_times_ms(&[
            0.0, 500.0, 1_000.0, 1_000.0, 2_000.0,
        ]);
        cfg.replay = Some(std::sync::Arc::new(schedule));
        let r = run_single(&cfg, &MinosConfig::baseline(), 0, false, None).unwrap();
        assert_eq!(r.successful(), 5, "every scheduled arrival must complete");
        let mut subs: Vec<f64> =
            r.records.iter().map(|x| x.submitted_at.as_ms()).collect();
        subs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(subs, vec![0.0, 500.0, 1_000.0, 1_000.0, 2_000.0]);
    }

    #[test]
    fn replay_is_deterministic() {
        let mut cfg = ExperimentConfig::smoke(1, 22);
        let schedule = std::sync::Arc::new(crate::trace::ReplaySchedule::from_times_ms(
            &(0..200).map(|i| i as f64 * 400.0).collect::<Vec<f64>>(),
        ));
        cfg.replay = Some(schedule);
        let minos = MinosConfig {
            elysium_threshold_ms: 380.0,
            ..MinosConfig::paper_default()
        };
        let a = run_single(&cfg, &minos, 0, false, None).unwrap();
        let b = run_single(&cfg, &minos, 0, false, None).unwrap();
        assert_eq!(a.successful(), b.successful());
        assert_eq!(a.terminations, b.terminations);
        assert!((a.total_cost_usd() - b.total_cost_usd()).abs() < 1e-15);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.completed_at, y.completed_at);
        }
    }

    #[test]
    fn payload_scale_lengthens_execution() {
        let schedule = |scale: f64| {
            std::sync::Arc::new(crate::trace::ReplaySchedule {
                arrivals: (0..50)
                    .map(|i| (SimTime::from_ms(i as f64 * 5_000.0), scale))
                    .collect(),
            })
        };
        let mut small = ExperimentConfig::smoke(0, 23);
        small.replay = Some(schedule(1.0));
        let mut big = ExperimentConfig::smoke(0, 23);
        big.replay = Some(schedule(3.0));
        let base = MinosConfig::baseline();
        let r_small = run_single(&small, &base, 0, false, None).unwrap();
        let r_big = run_single(&big, &base, 0, false, None).unwrap();
        let m_small = crate::stats::mean(&r_small.exec_durations());
        let m_big = crate::stats::mean(&r_big.exec_durations());
        assert!(
            m_big > m_small * 1.8,
            "3× payload should roughly triple the data phases: {m_small} vs {m_big}"
        );
    }

    #[test]
    fn trace_run_reports_per_function() {
        let trace = crate::trace::SynthConfig {
            n_functions: 3,
            hours: 0.05,
            total_rate_rps: 2.0,
            seed: 7,
            ..Default::default()
        }
        .generate();
        let registry = crate::trace::FunctionRegistry::demo(trace.n_functions());
        let cfg = ExperimentConfig::smoke(1, 31);
        let o = run_trace(&cfg, &registry, &trace, None).unwrap();
        // One outcome per function the trace actually invokes (a bursty
        // function can legitimately stay silent over a short window).
        let ids: Vec<FunctionId> = o.per_function.iter().map(|f| f.id).collect();
        assert_eq!(ids, trace.function_ids());
        for f in &o.per_function {
            assert_eq!(
                f.result.successful(),
                f.arrivals as u64,
                "function {} must complete every trace arrival",
                f.name
            );
            assert!(f.pretest.threshold_ms.is_finite() && f.pretest.threshold_ms > 0.0);
            assert_eq!(f.arrivals, trace.count_for(f.id));
        }
        assert_eq!(o.total_completed(), trace.len() as u64);
        assert_eq!(o.total_arrivals(), trace.len());
        assert!(o.total_cost_usd() > 0.0);
        // Deployments are independent: per-function thresholds differ
        // (different lotteries). f0 (hot Poisson) and f2 (diurnal) always
        // have arrivals at these rates.
        let th = |id: u32| {
            o.per_function
                .iter()
                .find(|f| f.id == FunctionId(id))
                .expect("function present")
                .pretest
                .threshold_ms
        };
        assert_ne!(th(0), th(2));
    }

    #[test]
    fn trace_run_rejects_uncovered_function_ids() {
        use crate::trace::{FunctionId as Fid, Trace, TraceRecord};
        let trace = Trace::from_records(vec![
            TraceRecord { t: SimTime::ZERO, function: Fid(0), payload_scale: 1.0 },
            TraceRecord {
                t: SimTime::from_ms(10.0),
                function: Fid(3),
                payload_scale: 1.0,
            },
        ]);
        let registry = crate::trace::FunctionRegistry::demo(2);
        let cfg = ExperimentConfig::smoke(0, 61);
        let err = run_trace(&cfg, &registry, &trace, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("registry"), "unhelpful error: {msg}");
    }
}

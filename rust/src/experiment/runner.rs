//! Experiment orchestration: compose worlds, kernel, and thread pools
//! into the paper's runs.
//!
//! The discrete-event *loop* lives in `sim::kernel` and the domain
//! *semantics* live in `experiment::world::MinosWorld` (single
//! deployment) and `experiment::cluster::RegionWorld` (multi-function
//! shared-node regions); this module only wires them together:
//!
//! - [`run_single`] — one condition (Minos or baseline) on one day;
//! - [`run_pretest`] — threshold calibration (paper §II-B-a);
//! - [`run_paired`] / [`run_paired_threads`] — both paper conditions on
//!   the identical platform draw, optionally on two threads;
//! - [`run_week`] / [`run_week_threads`] — seven paired days, optionally
//!   with days fanned out over a thread pool;
//! - [`run_trace`] / [`run_trace_threads`] — multi-function trace replay
//!   with isolated per-function deployments;
//! - [`run_trace_paired`] — per-function paired Minos-vs-baseline trace
//!   replays (per-function improvement figures).
//!
//! All `_threads` variants take the crate-wide thread convention
//! (0 = auto, 1 = sequential) and produce results bit-identical to the
//! sequential order at any thread count: every work item forks its own
//! seeded RNG streams and results merge by index
//! (`util::parallel::map_indexed`).

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::pretest::PretestReport;
use crate::coordinator::MinosConfig;
use crate::runtime::Runtime;
use crate::sim::Simulation;
use crate::trace::{
    FunctionId, FunctionProfile, FunctionRegistry, ReplaySchedule, Trace,
};
use crate::util::parallel;

use super::config::ExperimentConfig;
use super::metrics::RunResult;
use super::world::MinosWorld;

/// Run one condition (Minos or baseline) for one day.
///
/// `salt` separates the placement lottery between pre-test and main runs;
/// paired conditions use the same salt. `runtime` enables real artifact
/// execution per completed invocation.
pub fn run_single(
    cfg: &ExperimentConfig,
    minos: &MinosConfig,
    salt: u64,
    bench_warm: bool,
    runtime: Option<&Runtime>,
) -> Result<RunResult> {
    let mut sim = Simulation::new(MinosWorld::new(cfg, minos, salt, bench_warm, runtime));
    let Simulation { world, events } = &mut sim;
    world.seed_initial(events);
    sim.run()?;
    Ok(sim.into_world().finish())
}

/// Pre-test (paper §II-B-a): a short run that benchmarks but never
/// terminates, then calibrates the threshold at the target percentile.
pub fn run_pretest(cfg: &ExperimentConfig, runtime: Option<&Runtime>) -> Result<PretestReport> {
    let mut pretest_cfg = cfg.clone();
    pretest_cfg.vus = cfg.pretest_vus.clone();
    // The pre-test is always the paper's closed-loop calibration workload,
    // even when the main run replays a trace — and always records in full
    // (threshold calibration needs the raw score vector; the pre-test is
    // short, so memory is not a concern even under streaming main runs).
    // It also always runs the fixed gate at threshold ∞ (benchmark
    // everything, terminate nothing), whatever policy the main run uses.
    pretest_cfg.replay = None;
    pretest_cfg.metrics = super::metrics::MetricsMode::Full;
    pretest_cfg.policy = crate::policy::PolicySpec::Fixed;
    // Pre-tests are calibration machinery, not the run under observation:
    // keep them out of timelines, gauges, and probe counters.
    pretest_cfg.obs = crate::obs::ObsConfig::off();
    // Calibration must stay churn-free and unbounded: thresholds measured
    // on a dying or shedding fleet would poison every main-run arm.
    pretest_cfg.fault = crate::fault::FaultConfig::default();
    pretest_cfg.retry = crate::fault::RetryConfig::default();
    pretest_cfg.admission = crate::fault::AdmissionConfig::default();
    // Same for the attempt recorder: bounds are about the main run.
    pretest_cfg.record_attempts = false;
    let minos = MinosConfig {
        enabled: true,
        elysium_threshold_ms: f64::INFINITY,
        ..cfg.minos.clone()
    };
    let run = run_single(&pretest_cfg, &minos, 1, cfg.pretest_bench_warm, runtime)?;
    Ok(PretestReport::from_scores(run.bench_scores().to_vec(), cfg.elysium_percentile))
}

/// Relabel a run's flight-recorder track. Worlds capture under a generic
/// label; the orchestrator knows the run's identity (day, arm, function).
fn label_obs(result: &mut RunResult, track: String) {
    if let Some(obs) = result.obs.as_deref_mut() {
        obs.track = track;
    }
}

/// Both paper conditions on the identical platform draw.
#[derive(Debug)]
pub struct PairedOutcome {
    pub day: u32,
    pub pretest: PretestReport,
    pub minos: RunResult,
    pub baseline: RunResult,
}

impl PairedOutcome {
    /// Mean analysis-duration improvement, % (Fig. 4's headline measure).
    /// Works over both sink modes (exact mean / Welford mean).
    pub fn analysis_improvement_pct(&self) -> f64 {
        let b = self.baseline.analysis_mean_ms();
        let m = self.minos.analysis_mean_ms();
        (b - m) / b * 100.0
    }

    /// Median analysis-duration improvement, % (exact / P² by mode).
    pub fn analysis_median_improvement_pct(&self) -> f64 {
        let b = self.baseline.analysis_median_ms();
        let m = self.minos.analysis_median_ms();
        (b - m) / b * 100.0
    }

    /// Extra successful requests, % (Fig. 5's measure).
    pub fn successful_requests_improvement_pct(&self) -> f64 {
        let b = self.baseline.successful() as f64;
        (self.minos.successful() as f64 - b) / b * 100.0
    }

    /// Cost-per-success saving, % (Fig. 6's measure; positive = cheaper).
    pub fn cost_saving_pct(&self) -> f64 {
        let b = self.baseline.cost_per_million_usd();
        (b - self.minos.cost_per_million_usd()) / b * 100.0
    }
}

/// Run pre-test + paired conditions for one configured day (sequential).
pub fn run_paired(cfg: &ExperimentConfig, runtime: Option<&Runtime>) -> Result<PairedOutcome> {
    run_paired_threads(cfg, runtime, 1)
}

/// Like [`run_paired`], but the two conditions — independent simulations
/// on the identical platform draw — run concurrently when `threads` allows
/// (0 = auto). Results are bit-identical to the sequential order; with a
/// `runtime` the run stays sequential (PJRT handles are not `Sync`).
pub fn run_paired_threads(
    cfg: &ExperimentConfig,
    runtime: Option<&Runtime>,
    threads: usize,
) -> Result<PairedOutcome> {
    let pretest = run_pretest(cfg, runtime)?;
    let minos_cfg = MinosConfig {
        enabled: true,
        elysium_threshold_ms: pretest.threshold_ms,
        ..cfg.minos.clone()
    };
    let baseline_cfg = MinosConfig { enabled: false, ..cfg.minos.clone() };
    // The paper deploys baseline and Minos as *separate functions* run at
    // the same time: same platform day, independent instance lotteries.
    let (mut minos, mut baseline) = if parallel::resolve_threads(threads) >= 2
        && runtime.is_none()
    {
        let (minos_res, baseline_res) = std::thread::scope(|s| {
            let handle = s.spawn(|| run_single(cfg, &minos_cfg, 0, false, None));
            let baseline = run_single(cfg, &baseline_cfg, 2, false, None);
            let minos = match handle.join() {
                Ok(r) => r,
                Err(panic) => std::panic::resume_unwind(panic),
            };
            (minos, baseline)
        });
        (minos_res?, baseline_res?)
    } else {
        (
            run_single(cfg, &minos_cfg, 0, false, runtime)?,
            run_single(cfg, &baseline_cfg, 2, false, runtime)?,
        )
    };
    label_obs(&mut minos, format!("day{}/minos", cfg.day));
    label_obs(&mut baseline, format!("day{}/baseline", cfg.day));
    Ok(PairedOutcome { day: cfg.day, pretest, minos, baseline })
}

/// The paper's full week: seven paired days (sequential).
pub fn run_week(
    base: &ExperimentConfig,
    days: u32,
    runtime: Option<&Runtime>,
) -> Result<Vec<PairedOutcome>> {
    run_week_threads(base, days, runtime, 1)
}

/// Like [`run_week`], but days fan out over a thread pool (each day is a
/// self-contained paired run with its own seed). Bit-identical to the
/// sequential order at any `threads`.
pub fn run_week_threads(
    base: &ExperimentConfig,
    days: u32,
    runtime: Option<&Runtime>,
    threads: usize,
) -> Result<Vec<PairedOutcome>> {
    let day_cfg = |d: u32| {
        let mut cfg = base.clone();
        cfg.day = d;
        cfg.seed = base.seed + d as u64;
        cfg
    };
    if parallel::resolve_threads(threads) >= 2 && runtime.is_none() {
        parallel::try_map_indexed(days as usize, threads, |d| {
            run_paired(&day_cfg(d as u32), None)
        })
    } else {
        (0..days).map(|d| run_paired(&day_cfg(d), runtime)).collect()
    }
}

/// Per-function outcome of a trace replay.
#[derive(Debug)]
pub struct FunctionRunOutcome {
    pub id: FunctionId,
    pub name: String,
    /// Arrivals the trace addressed to this function.
    pub arrivals: usize,
    /// This function's own pre-test (its threshold calibration).
    pub pretest: PretestReport,
    pub result: RunResult,
}

/// Outcome of replaying a multi-function trace.
#[derive(Debug)]
pub struct TraceOutcome {
    pub per_function: Vec<FunctionRunOutcome>,
}

impl TraceOutcome {
    pub fn total_arrivals(&self) -> usize {
        self.per_function.iter().map(|f| f.arrivals).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.successful()).sum()
    }

    pub fn total_cost_usd(&self) -> f64 {
        self.per_function.iter().map(|f| f.result.total_cost_usd()).sum()
    }

    pub fn total_terminations(&self) -> u64 {
        self.per_function.iter().map(|f| f.result.terminations).sum()
    }
}

/// Build the per-function deployment config `run_trace` and
/// `run_trace_paired` share: the function's own profile, percentile, and
/// deterministic per-deployment seed.
fn deployment_cfg(base: &ExperimentConfig, profile: &FunctionProfile) -> ExperimentConfig {
    let mut cfg = base.clone();
    cfg.function = profile.spec.clone();
    cfg.minos = profile.minos.clone();
    cfg.elysium_percentile = profile.elysium_percentile;
    // Per-function policy override (trace registry) beats the
    // experiment-wide default.
    if let Some(policy) = profile.policy {
        cfg.policy = policy;
    }
    cfg.open_loop_rate_rps = None;
    cfg.replay = None;
    // Separate deployments get separate platform lotteries.
    cfg.seed = base
        .seed
        .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(profile.id.0 as u64 + 1));
    cfg
}

/// Pre-test + replay one function's slice of a trace.
fn trace_item(
    base: &ExperimentConfig,
    profile: &FunctionProfile,
    schedule: Arc<ReplaySchedule>,
    runtime: Option<&Runtime>,
) -> Result<FunctionRunOutcome> {
    let mut cfg = deployment_cfg(base, profile);
    // Calibrate this function's threshold (closed-loop pre-test,
    // paper §II-B-a), then replay its slice of the trace.
    let pretest = run_pretest(&cfg, runtime)?;
    let minos_cfg = MinosConfig {
        elysium_threshold_ms: pretest.threshold_ms,
        ..cfg.minos.clone()
    };
    let arrivals = schedule.len();
    cfg.replay = Some(schedule);
    let mut result = run_single(&cfg, &minos_cfg, 0, false, runtime)?;
    label_obs(&mut result, profile.name.clone());
    Ok(FunctionRunOutcome {
        id: profile.id,
        name: profile.name.clone(),
        arrivals,
        pretest,
        result,
    })
}

/// Split a trace into the non-empty per-function work items.
fn trace_items<'r>(
    registry: &'r FunctionRegistry,
    trace: &Trace,
) -> Result<Vec<(&'r FunctionProfile, Arc<ReplaySchedule>)>> {
    // Refuse partial coverage: silently dropping records whose function id
    // has no profile would make the totals read as a complete replay.
    anyhow::ensure!(
        trace.n_functions() <= registry.len(),
        "trace addresses function ids up to {} but the registry defines only {} \
         profiles",
        trace.n_functions().saturating_sub(1),
        registry.len()
    );
    // One O(N) pass splits the trace into per-function schedules.
    let mut schedules = trace.schedules(registry.len());
    let mut items = Vec::new();
    for profile in registry.iter() {
        let schedule = std::mem::take(&mut schedules[profile.id.0 as usize]);
        if schedule.is_empty() {
            continue;
        }
        items.push((profile, Arc::new(schedule)));
    }
    Ok(items)
}

/// Replay a multi-function trace: each function in the registry is its own
/// deployment (own warm pool, own instance lottery — exactly how FaaS
/// platforms isolate functions), pre-tested for its own elysium threshold,
/// then driven by the trace's arrivals for that function id. Functions the
/// trace never invokes are skipped; region ids are ignored (use
/// `experiment::cluster::run_cluster` for multi-region shared-node
/// replay).
pub fn run_trace(
    base: &ExperimentConfig,
    registry: &FunctionRegistry,
    trace: &Trace,
    runtime: Option<&Runtime>,
) -> Result<TraceOutcome> {
    run_trace_threads(base, registry, trace, runtime, 1)
}

/// Like [`run_trace`], but the per-function items (pre-test + replay) fan
/// out over a thread pool. Bit-identical to the sequential order; with a
/// `runtime` the run stays sequential.
pub fn run_trace_threads(
    base: &ExperimentConfig,
    registry: &FunctionRegistry,
    trace: &Trace,
    runtime: Option<&Runtime>,
    threads: usize,
) -> Result<TraceOutcome> {
    let items = trace_items(registry, trace)?;
    let per_function = if parallel::resolve_threads(threads) >= 2 && runtime.is_none() {
        parallel::try_map_indexed(items.len(), threads, |i| {
            let (profile, schedule) = &items[i];
            trace_item(base, profile, schedule.clone(), None)
        })?
    } else {
        let mut out = Vec::with_capacity(items.len());
        for (profile, schedule) in &items {
            out.push(trace_item(base, profile, schedule.clone(), runtime)?);
        }
        out
    };
    Ok(TraceOutcome { per_function })
}

/// Per-function paired Minos-vs-baseline outcome of a trace replay.
#[derive(Debug)]
pub struct FunctionPairedOutcome {
    pub id: FunctionId,
    pub name: String,
    pub arrivals: usize,
    pub pretest: PretestReport,
    pub minos: RunResult,
    pub baseline: RunResult,
}

impl FunctionPairedOutcome {
    /// Mean analysis-duration improvement for this function, % (works
    /// over both sink modes).
    pub fn analysis_improvement_pct(&self) -> f64 {
        let b = self.baseline.analysis_mean_ms();
        let m = self.minos.analysis_mean_ms();
        (b - m) / b * 100.0
    }

    /// Cost-per-success saving for this function, % (positive = cheaper).
    pub fn cost_saving_pct(&self) -> f64 {
        let b = self.baseline.cost_per_million_usd();
        (b - self.minos.cost_per_million_usd()) / b * 100.0
    }
}

/// Outcome of a paired trace replay: per-function improvement figures.
#[derive(Debug)]
pub struct TracePairedOutcome {
    pub per_function: Vec<FunctionPairedOutcome>,
}

/// Replay every function's trace slice under *both* conditions — Minos
/// and baseline on the identical platform draw (same day, independent
/// salts, exactly like [`run_paired`]) — yielding per-function
/// improvement figures. Items fan out over a thread pool.
pub fn run_trace_paired(
    base: &ExperimentConfig,
    registry: &FunctionRegistry,
    trace: &Trace,
    threads: usize,
) -> Result<TracePairedOutcome> {
    let items = trace_items(registry, trace)?;
    let per_function = parallel::try_map_indexed(items.len(), threads, |i| {
        let (profile, schedule) = &items[i];
        let mut cfg = deployment_cfg(base, profile);
        let pretest = run_pretest(&cfg, None)?;
        let minos_cfg = MinosConfig {
            elysium_threshold_ms: pretest.threshold_ms,
            ..cfg.minos.clone()
        };
        let baseline_cfg = MinosConfig { enabled: false, ..cfg.minos.clone() };
        let arrivals = schedule.len();
        cfg.replay = Some(schedule.clone());
        let mut minos = run_single(&cfg, &minos_cfg, 0, false, None)?;
        let mut baseline = run_single(&cfg, &baseline_cfg, 2, false, None)?;
        label_obs(&mut minos, format!("{}/minos", profile.name));
        label_obs(&mut baseline, format!("{}/baseline", profile.name));
        Ok(FunctionPairedOutcome {
            id: profile.id,
            name: profile.name.clone(),
            arrivals,
            pretest,
            minos,
            baseline,
        })
    })?;
    Ok(TracePairedOutcome { per_function })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn smoke_run_completes_requests() {
        let cfg = ExperimentConfig::smoke(0, 7);
        let baseline = MinosConfig::baseline();
        let r = run_single(&cfg, &baseline, 0, false, None).unwrap();
        // 10 VUs × 120 s at ~4 s/request ⇒ ~300 requests.
        assert!(r.successful() > 150, "only {} successes", r.successful());
        assert!(r.terminations == 0, "baseline must not terminate");
        assert!(r.bench_scores().is_empty(), "baseline must not benchmark");
        assert_eq!(r.cold_starts as usize, 10);
    }

    #[test]
    fn minos_terminates_and_requeues() {
        let cfg = ExperimentConfig::smoke(1, 8); // high-sigma day
        let minos = MinosConfig {
            elysium_threshold_ms: 350.0, // ~median ⇒ ~half terminated
            ..MinosConfig::paper_default()
        };
        let r = run_single(&cfg, &minos, 0, false, None).unwrap();
        assert!(r.terminations > 0, "expected terminations");
        assert!(r.successful() > 100);
        // Terminated cost events exist and carry positive cost.
        assert!(r.cost_events().iter().any(|e| e.terminated && e.usd > 0.0));
    }

    #[test]
    fn pretest_calibrates_threshold() {
        let cfg = ExperimentConfig::paper_day(0);
        let report = run_pretest(&cfg, None).unwrap();
        assert!(report.scores_ms.len() >= 10, "{} scores", report.scores_ms.len());
        assert!(report.threshold_ms > 100.0 && report.threshold_ms < 1_500.0);
    }

    #[test]
    fn paired_runs_share_platform() {
        let cfg = ExperimentConfig::smoke(0, 9);
        let o = run_paired(&cfg, None).unwrap();
        // Conditions ran: both have successes; Minos has bench scores.
        assert!(o.minos.successful() > 0 && o.baseline.successful() > 0);
        assert!(!o.minos.bench_scores().is_empty());
        assert!(o.baseline.bench_scores().is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig::smoke(2, 11);
        let m = MinosConfig::baseline();
        let a = run_single(&cfg, &m, 0, false, None).unwrap();
        let b = run_single(&cfg, &m, 0, false, None).unwrap();
        assert_eq!(a.successful(), b.successful());
        assert!((a.total_cost_usd() - b.total_cost_usd()).abs() < 1e-15);
        assert_eq!(a.records().len(), b.records().len());
        for (x, y) in a.records().iter().zip(b.records()) {
            assert_eq!(x.completed_at, y.completed_at);
        }
    }

    #[test]
    fn paired_is_bit_identical_across_thread_counts() {
        let mut cfg = ExperimentConfig::smoke(1, 12);
        let schedule = std::sync::Arc::new(crate::trace::ReplaySchedule::from_times_ms(
            &(0..300).map(|i| i as f64 * 350.0).collect::<Vec<f64>>(),
        ));
        cfg.replay = Some(schedule);
        let seq = run_paired_threads(&cfg, None, 1).unwrap();
        let par = run_paired_threads(&cfg, None, 8).unwrap();
        assert_eq!(seq.pretest.threshold_ms.to_bits(), par.pretest.threshold_ms.to_bits());
        for (a, b) in [(&seq.minos, &par.minos), (&seq.baseline, &par.baseline)] {
            assert_eq!(a.successful(), b.successful());
            assert_eq!(a.terminations, b.terminations);
            assert_eq!(
                a.total_cost_usd().to_bits(),
                b.total_cost_usd().to_bits(),
                "thread count changed paired-replay metrics"
            );
            assert_eq!(a.records().len(), b.records().len());
            for (x, y) in a.records().iter().zip(b.records()) {
                assert_eq!(x.completed_at, y.completed_at);
                assert_eq!(x.inv_id, y.inv_id);
            }
        }
    }

    #[test]
    fn week_parallel_matches_sequential() {
        let mut base = ExperimentConfig::smoke(0, 14);
        base.vus.horizon = SimTime::from_secs(60.0);
        let seq = run_week_threads(&base, 2, None, 1).unwrap();
        let par = run_week_threads(&base, 2, None, 4).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.day, b.day);
            assert_eq!(a.minos.successful(), b.minos.successful());
            assert_eq!(
                a.minos.total_cost_usd().to_bits(),
                b.minos.total_cost_usd().to_bits()
            );
            assert_eq!(a.baseline.successful(), b.baseline.successful());
        }
    }

    #[test]
    fn open_loop_poisson_arrivals() {
        let mut cfg = ExperimentConfig::smoke(0, 15);
        cfg.open_loop_rate_rps = Some(3.0);
        let baseline = MinosConfig::baseline();
        let r = run_single(&cfg, &baseline, 0, false, None).unwrap();
        // ~3 req/s over 120 s => ~360 arrivals; all must complete.
        let n = r.successful();
        assert!((250..=470).contains(&(n as i64)), "open-loop completions: {n}");
        // Open loop scales out past the closed-loop's 10 instances when
        // arrivals burst.
        assert!(r.cold_starts >= 10);
    }

    #[test]
    fn open_loop_minos_still_wins() {
        let mut cfg = ExperimentConfig::smoke(1, 16);
        cfg.vus.horizon = crate::sim::SimTime::from_secs(300.0);
        cfg.open_loop_rate_rps = Some(3.0);
        let o = run_paired(&cfg, None).unwrap();
        assert!(
            o.analysis_improvement_pct() > 0.0,
            "minos should win under open-loop arrivals: {:+.2}%",
            o.analysis_improvement_pct()
        );
    }

    #[test]
    fn retry_cap_bounds_attempts() {
        let cfg = ExperimentConfig::smoke(1, 13);
        let minos = MinosConfig {
            // Impossible threshold: every benchmark fails ⇒ every request
            // must be saved by the emergency exit after retry_cap tries.
            elysium_threshold_ms: 0.0,
            ..MinosConfig::paper_default()
        };
        let r = run_single(&cfg, &minos, 0, false, None).unwrap();
        assert!(r.successful() > 0, "emergency exit must save requests");
        // Every cold-path completion was saved by the emergency exit at
        // exactly the cap; warm re-uses of the forced-pass instances run
        // without a benchmark on the first attempt.
        let mut saw_forced = 0;
        for rec in r.records() {
            if rec.cold {
                assert_eq!(rec.attempts, minos.retry_cap + 1);
                assert!(rec.forced);
                saw_forced += 1;
            } else {
                assert_eq!(rec.attempts, 1);
                assert!(!rec.forced);
            }
            assert!(rec.attempts <= minos.retry_cap + 1, "cap exceeded");
        }
        assert!(saw_forced > 0, "no forced cold completions observed");
        assert_eq!(r.forced_passes, saw_forced);
    }

    #[test]
    fn replay_arrivals_follow_schedule() {
        let mut cfg = ExperimentConfig::smoke(0, 21);
        let schedule = crate::trace::ReplaySchedule::from_times_ms(&[
            0.0, 500.0, 1_000.0, 1_000.0, 2_000.0,
        ]);
        cfg.replay = Some(std::sync::Arc::new(schedule));
        let r = run_single(&cfg, &MinosConfig::baseline(), 0, false, None).unwrap();
        assert_eq!(r.successful(), 5, "every scheduled arrival must complete");
        let mut subs: Vec<f64> =
            r.records().iter().map(|x| x.submitted_at.as_ms()).collect();
        subs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(subs, vec![0.0, 500.0, 1_000.0, 1_000.0, 2_000.0]);
    }

    #[test]
    fn replay_is_deterministic() {
        let mut cfg = ExperimentConfig::smoke(1, 22);
        let schedule = std::sync::Arc::new(crate::trace::ReplaySchedule::from_times_ms(
            &(0..200).map(|i| i as f64 * 400.0).collect::<Vec<f64>>(),
        ));
        cfg.replay = Some(schedule);
        let minos = MinosConfig {
            elysium_threshold_ms: 380.0,
            ..MinosConfig::paper_default()
        };
        let a = run_single(&cfg, &minos, 0, false, None).unwrap();
        let b = run_single(&cfg, &minos, 0, false, None).unwrap();
        assert_eq!(a.successful(), b.successful());
        assert_eq!(a.terminations, b.terminations);
        assert!((a.total_cost_usd() - b.total_cost_usd()).abs() < 1e-15);
        for (x, y) in a.records().iter().zip(b.records()) {
            assert_eq!(x.completed_at, y.completed_at);
        }
    }

    #[test]
    fn saturated_platform_retries_until_served() {
        // A one-instance quota with a burst of simultaneous arrivals:
        // every placement past the first hits Placement::Saturated and
        // must untake + retry until the instance frees up. All requests
        // still complete, serialized through the single instance.
        let mut cfg = ExperimentConfig::smoke(0, 25);
        cfg.platform.max_instances = 1;
        let schedule = crate::trace::ReplaySchedule::from_times_ms(&[0.0; 12]);
        cfg.replay = Some(std::sync::Arc::new(schedule));
        let r = run_single(&cfg, &MinosConfig::baseline(), 0, false, None).unwrap();
        assert_eq!(r.successful(), 12, "saturation must delay, not drop, requests");
        // The single instance serialized the work: completions are spread
        // out by at least one execution each (~2.9 s nominal; even on the
        // fastest admissible instance an execution exceeds ~1 s).
        let mut completions: Vec<f64> =
            r.records().iter().map(|x| x.completed_at.as_ms()).collect();
        completions.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in completions.windows(2) {
            assert!(w[1] - w[0] > 800.0, "overlapping executions on 1 instance");
        }
        // Each request needed at most one cold start (no terminations).
        assert_eq!(r.terminations, 0);
        assert!(r.cold_starts <= 2, "quota of 1 cannot cold-start concurrently");
    }

    #[test]
    fn payload_scale_lengthens_execution() {
        let schedule = |scale: f64| {
            std::sync::Arc::new(crate::trace::ReplaySchedule {
                arrivals: (0..50)
                    .map(|i| (SimTime::from_ms(i as f64 * 5_000.0), scale))
                    .collect(),
            })
        };
        let mut small = ExperimentConfig::smoke(0, 23);
        small.replay = Some(schedule(1.0));
        let mut big = ExperimentConfig::smoke(0, 23);
        big.replay = Some(schedule(3.0));
        let base = MinosConfig::baseline();
        let r_small = run_single(&small, &base, 0, false, None).unwrap();
        let r_big = run_single(&big, &base, 0, false, None).unwrap();
        let m_small = crate::stats::mean(&r_small.exec_durations());
        let m_big = crate::stats::mean(&r_big.exec_durations());
        assert!(
            m_big > m_small * 1.8,
            "3× payload should roughly triple the data phases: {m_small} vs {m_big}"
        );
    }

    #[test]
    fn trace_run_reports_per_function() {
        let trace = crate::trace::SynthConfig {
            n_functions: 3,
            hours: 0.05,
            total_rate_rps: 2.0,
            seed: 7,
            ..Default::default()
        }
        .generate();
        let registry = crate::trace::FunctionRegistry::demo(trace.n_functions());
        let cfg = ExperimentConfig::smoke(1, 31);
        let o = run_trace(&cfg, &registry, &trace, None).unwrap();
        // One outcome per function the trace actually invokes (a bursty
        // function can legitimately stay silent over a short window).
        let ids: Vec<FunctionId> = o.per_function.iter().map(|f| f.id).collect();
        assert_eq!(ids, trace.function_ids());
        for f in &o.per_function {
            assert_eq!(
                f.result.successful(),
                f.arrivals as u64,
                "function {} must complete every trace arrival",
                f.name
            );
            assert!(f.pretest.threshold_ms.is_finite() && f.pretest.threshold_ms > 0.0);
            assert_eq!(f.arrivals, trace.count_for(f.id));
        }
        assert_eq!(o.total_completed(), trace.len() as u64);
        assert_eq!(o.total_arrivals(), trace.len());
        assert!(o.total_cost_usd() > 0.0);
        // Deployments are independent: per-function thresholds differ
        // (different lotteries). f0 (hot Poisson) and f2 (diurnal) always
        // have arrivals at these rates.
        let th = |id: u32| {
            o.per_function
                .iter()
                .find(|f| f.id == FunctionId(id))
                .expect("function present")
                .pretest
                .threshold_ms
        };
        assert_ne!(th(0), th(2));
    }

    #[test]
    fn trace_parallel_matches_sequential() {
        let trace = crate::trace::SynthConfig {
            n_functions: 4,
            hours: 0.04,
            total_rate_rps: 3.0,
            seed: 17,
            ..Default::default()
        }
        .generate();
        let registry = crate::trace::FunctionRegistry::demo(trace.n_functions());
        let cfg = ExperimentConfig::smoke(0, 41);
        let seq = run_trace_threads(&cfg, &registry, &trace, None, 1).unwrap();
        let par = run_trace_threads(&cfg, &registry, &trace, None, 8).unwrap();
        assert_eq!(seq.per_function.len(), par.per_function.len());
        for (a, b) in seq.per_function.iter().zip(&par.per_function) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.pretest.threshold_ms.to_bits(), b.pretest.threshold_ms.to_bits());
            assert_eq!(a.result.successful(), b.result.successful());
            assert_eq!(
                a.result.total_cost_usd().to_bits(),
                b.result.total_cost_usd().to_bits()
            );
        }
    }

    #[test]
    fn trace_paired_reports_per_function_improvements() {
        let trace = crate::trace::SynthConfig {
            n_functions: 2,
            hours: 0.06,
            total_rate_rps: 3.0,
            seed: 19,
            ..Default::default()
        }
        .generate();
        let registry = crate::trace::FunctionRegistry::demo(trace.n_functions());
        let cfg = ExperimentConfig::smoke(1, 43);
        let o = run_trace_paired(&cfg, &registry, &trace, 2).unwrap();
        assert_eq!(o.per_function.len(), trace.function_ids().len());
        for f in &o.per_function {
            assert_eq!(f.minos.successful(), f.arrivals as u64);
            assert_eq!(f.baseline.successful(), f.arrivals as u64);
            assert!(f.baseline.bench_scores().is_empty(), "baseline must not benchmark");
            assert!(f.analysis_improvement_pct().is_finite());
            assert!(f.cost_saving_pct().is_finite());
        }
    }

    #[test]
    fn trace_run_rejects_uncovered_function_ids() {
        use crate::platform::RegionId;
        use crate::trace::{FunctionId as Fid, Trace, TraceRecord};
        let trace = Trace::from_records(vec![
            TraceRecord {
                t: SimTime::ZERO,
                function: Fid(0),
                region: RegionId(0),
                payload_scale: 1.0,
            },
            TraceRecord {
                t: SimTime::from_ms(10.0),
                function: Fid(3),
                region: RegionId(0),
                payload_scale: 1.0,
            },
        ]);
        let registry = crate::trace::FunctionRegistry::demo(2);
        let cfg = ExperimentConfig::smoke(0, 61);
        let err = run_trace(&cfg, &registry, &trace, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("registry"), "unhelpful error: {msg}");
    }
}

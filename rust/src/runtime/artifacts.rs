//! Artifact discovery and fixture loading.
//!
//! `make artifacts` populates `artifacts/` with HLO text modules, raw-f32
//! fixture tensors, and `meta.json` (shapes + oracle outputs). This module
//! finds and validates them so the runtime and integration tests have one
//! authoritative view.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Locations of the AOT artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub linreg_hlo: PathBuf,
    pub bench_hlo: PathBuf,
    pub meta: Json,
}

impl ArtifactStore {
    /// Discover artifacts under `dir` and validate `meta.json`.
    pub fn discover(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let meta_text = fs::read_to_string(&meta_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                meta_path.display()
            )
        })?;
        let meta = json::parse(&meta_text)
            .map_err(|e| anyhow::anyhow!("parsing meta.json: {e}"))?;
        let rel = |key: &str| -> Result<PathBuf> {
            let name = meta
                .get("artifacts")
                .and_then(|a| a.get(key))
                .and_then(Json::as_str)
                .with_context(|| format!("meta.json missing artifacts.{key}"))?;
            Ok(dir.join(name))
        };
        let store = ArtifactStore {
            linreg_hlo: rel("linreg")?,
            bench_hlo: rel("benchmark")?,
            dir,
            meta,
        };
        for p in [&store.linreg_hlo, &store.bench_hlo] {
            if !p.exists() {
                bail!("artifact {} missing — run `make artifacts`", p.display());
            }
        }
        Ok(store)
    }

    /// Default location relative to the repo root / current directory.
    pub fn discover_default() -> Result<ArtifactStore> {
        for candidate in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(candidate).join("meta.json").exists() {
                return ArtifactStore::discover(candidate);
            }
        }
        ArtifactStore::discover("artifacts") // for the error message
    }

    /// Problem shapes recorded at lowering time.
    pub fn n_days(&self) -> usize {
        self.meta_num("n_days") as usize
    }

    pub fn n_features(&self) -> usize {
        self.meta_num("n_features") as usize
    }

    pub fn bench_dim(&self) -> usize {
        self.meta_num("bench_dim") as usize
    }

    fn meta_num(&self, key: &str) -> f64 {
        self.meta
            .get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("meta.json missing numeric {key}"))
    }

    /// Load the baked fixture tensors + oracle outputs.
    pub fn fixtures(&self) -> Result<Fixtures> {
        let read = |name: &str| -> Result<Vec<f32>> { read_f32(&self.dir.join(name)) };
        let oracle_pred = read("fixture_pred.f32")?;
        let oracle_bench = read("fixture_bench_sum.f32")?;
        Ok(Fixtures {
            x: read("fixture_x.f32")?,
            y: read("fixture_y.f32")?,
            x_next: read("fixture_xnext.f32")?,
            oracle_theta: read("fixture_theta.f32")?,
            oracle_pred: *oracle_pred.first().context("empty fixture_pred")?,
            bench_a: read("fixture_bench_a.f32")?,
            bench_b: read("fixture_bench_b.f32")?,
            oracle_bench_sum: *oracle_bench.first().context("empty bench_sum")?,
        })
    }
}

/// Fixed-seed inputs with Python-side (jnp oracle) expected outputs.
#[derive(Debug, Clone)]
pub struct Fixtures {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub x_next: Vec<f32>,
    pub oracle_theta: Vec<f32>,
    pub oracle_pred: f32,
    pub bench_a: Vec<f32>,
    pub bench_b: Vec<f32>,
    pub oracle_bench_sum: f32,
}

/// Read a little-endian raw f32 file.
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes =
        fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts`; they are skipped (not failed)
    // when the artifacts are absent so `cargo test` works pre-build.
    fn store() -> Option<ArtifactStore> {
        ArtifactStore::discover_default().ok()
    }

    #[test]
    fn discovers_and_validates() {
        let Some(s) = store() else { return };
        assert!(s.linreg_hlo.exists());
        assert!(s.bench_hlo.exists());
        assert_eq!(s.n_days(), 512);
        assert_eq!(s.n_features(), 16);
        assert_eq!(s.bench_dim(), 256);
    }

    #[test]
    fn fixtures_have_consistent_shapes() {
        let Some(s) = store() else { return };
        let f = s.fixtures().unwrap();
        assert_eq!(f.x.len(), s.n_days() * s.n_features());
        assert_eq!(f.y.len(), s.n_days());
        assert_eq!(f.x_next.len(), s.n_features());
        assert_eq!(f.oracle_theta.len(), s.n_features());
        assert_eq!(f.bench_a.len(), s.bench_dim() * s.bench_dim());
        assert!(f.oracle_pred.is_finite());
    }

    #[test]
    fn meta_pred_matches_fixture_file() {
        let Some(s) = store() else { return };
        let f = s.fixtures().unwrap();
        let meta_pred = s
            .meta
            .get("fixtures")
            .and_then(|m| m.get("pred"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((f.oracle_pred as f64 - meta_pred).abs() < 1e-3);
    }

    #[test]
    fn missing_dir_is_a_clear_error() {
        let err = ArtifactStore::discover("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}

//! XLA/PJRT runtime: loads the AOT-lowered HLO artifacts and executes them
//! on the request path.
//!
//! This is the boundary between the Rust coordinator (L3) and the JAX/
//! Pallas layers (L2/L1): `python/compile/aot.py` lowers the weather model
//! and the benchmark kernel to HLO **text** once at build time
//! (`make artifacts`); this module compiles those artifacts with the PJRT
//! CPU client and runs them with zero Python anywhere near the hot path.

pub mod artifacts;
pub mod calibrate;
pub mod engine;

pub use artifacts::{ArtifactStore, Fixtures};
pub use engine::Runtime;

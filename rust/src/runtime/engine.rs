//! The PJRT execution engine.
//!
//! Wraps the `xla` crate: one CPU client, one compiled executable per HLO
//! artifact, typed execute helpers that move f32 slices in and out. The
//! artifacts are lowered with `return_tuple=True`, so outputs decompose
//! with `to_tupleN`.
//!
//! The `xla` bindings are not part of the hermetic vendor set, so the real
//! engine is gated behind the `pjrt` cargo feature. Without it this module
//! compiles a stub [`Runtime`] with the identical API whose `load` fails
//! with a clear message — simulation-only commands (everything except
//! `--real` and `calibrate`) never notice the difference. Callers that want
//! to *skip* rather than fail check [`Runtime::pjrt_enabled`].

use std::time::Duration;

/// Output of one weather-analysis execution.
#[derive(Debug, Clone)]
pub struct LinregOutput {
    pub theta: Vec<f32>,
    pub prediction: f32,
    /// Wall-clock of the `execute` call (compile-side timing anchor).
    pub elapsed: Duration,
}

/// Output of one benchmark execution.
#[derive(Debug, Clone, Copy)]
pub struct BenchOutput {
    pub checksum: f32,
    pub elapsed: Duration,
}

#[cfg(feature = "pjrt")]
mod pjrt_engine {
    use std::time::Instant;

    use anyhow::{Context, Result};

    use super::{BenchOutput, LinregOutput};
    use crate::runtime::artifacts::ArtifactStore;

    /// Compiled executables bound to a PJRT CPU client.
    pub struct Runtime {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        linreg: xla::PjRtLoadedExecutable,
        bench: xla::PjRtLoadedExecutable,
        n_days: usize,
        n_features: usize,
        bench_dim: usize,
        /// Cumulative number of executions (metrics).
        pub executions: std::cell::Cell<u64>,
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Runtime")
                .field("n_days", &self.n_days)
                .field("n_features", &self.n_features)
                .field("bench_dim", &self.bench_dim)
                .field("executions", &self.executions.get())
                .finish()
        }
    }

    impl Runtime {
        /// Whether this build can execute artifacts through PJRT.
        pub const fn pjrt_enabled() -> bool {
            true
        }

        /// Compile both artifacts on a fresh CPU client.
        pub fn load(store: &ArtifactStore) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let compile = |path: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 artifact path")?,
                )
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", path.display()))
            };
            Ok(Runtime {
                linreg: compile(&store.linreg_hlo)?,
                bench: compile(&store.bench_hlo)?,
                n_days: store.n_days(),
                n_features: store.n_features(),
                bench_dim: store.bench_dim(),
                client,
                executions: std::cell::Cell::new(0),
            })
        }

        /// Load from the default artifact location.
        pub fn load_default() -> Result<Runtime> {
            Runtime::load(&ArtifactStore::discover_default()?)
        }

        pub fn n_days(&self) -> usize {
            self.n_days
        }

        pub fn n_features(&self) -> usize {
            self.n_features
        }

        pub fn bench_dim(&self) -> usize {
            self.bench_dim
        }

        /// Execute the weather analysis: OLS fit + next-day prediction.
        ///
        /// `x` is row-major `(n_days, n_features)`, `y` is `(n_days,)`,
        /// `x_next` is `(n_features,)`.
        pub fn exec_linreg(
            &self,
            x: &[f32],
            y: &[f32],
            x_next: &[f32],
        ) -> Result<LinregOutput> {
            anyhow::ensure!(
                x.len() == self.n_days * self.n_features,
                "x has {} elements, want {}",
                x.len(),
                self.n_days * self.n_features
            );
            anyhow::ensure!(y.len() == self.n_days, "y has {} elements", y.len());
            anyhow::ensure!(
                x_next.len() == self.n_features,
                "x_next has {} elements",
                x_next.len()
            );
            let lx = xla::Literal::vec1(x)
                .reshape(&[self.n_days as i64, self.n_features as i64])?;
            let ly = xla::Literal::vec1(y);
            let ln = xla::Literal::vec1(x_next);
            let start = Instant::now();
            let result = self.linreg.execute::<xla::Literal>(&[lx, ly, ln])?[0][0]
                .to_literal_sync()?;
            let elapsed = start.elapsed();
            self.executions.set(self.executions.get() + 1);
            let (theta_lit, pred_lit) = result.to_tuple2()?;
            Ok(LinregOutput {
                theta: theta_lit.to_vec::<f32>()?,
                prediction: pred_lit.to_vec::<f32>()?[0],
                elapsed,
            })
        }

        /// Execute the cold-start benchmark (tiled Pallas matmul checksum).
        pub fn exec_benchmark(&self, a: &[f32], b: &[f32]) -> Result<BenchOutput> {
            let n = self.bench_dim * self.bench_dim;
            anyhow::ensure!(a.len() == n && b.len() == n, "benchmark inputs must be {n}");
            let la = xla::Literal::vec1(a)
                .reshape(&[self.bench_dim as i64, self.bench_dim as i64])?;
            let lb = xla::Literal::vec1(b)
                .reshape(&[self.bench_dim as i64, self.bench_dim as i64])?;
            let start = Instant::now();
            let result =
                self.bench.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
            let elapsed = start.elapsed();
            self.executions.set(self.executions.get() + 1);
            let checksum_lit = result.to_tuple1()?;
            Ok(BenchOutput { checksum: checksum_lit.to_vec::<f32>()?[0], elapsed })
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_engine::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub_engine {
    use anyhow::{bail, Result};

    use super::{BenchOutput, LinregOutput};
    use crate::runtime::artifacts::ArtifactStore;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: minos was built without the \
         `pjrt` feature (the `xla` bindings are not in the hermetic vendor set); \
         simulation-only commands work without it";

    /// API-identical stand-in compiled when the `pjrt` feature is off.
    /// `load` always fails, so no instance of this type ever exists at
    /// runtime; the methods only satisfy the call sites.
    pub struct Runtime {
        /// Cumulative number of executions (always 0 in the stub).
        pub executions: std::cell::Cell<u64>,
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Runtime").field("pjrt", &false).finish()
        }
    }

    impl Runtime {
        /// Whether this build can execute artifacts through PJRT.
        pub const fn pjrt_enabled() -> bool {
            false
        }

        /// Always fails with a clear message in stub builds.
        pub fn load(_store: &ArtifactStore) -> Result<Runtime> {
            bail!("{UNAVAILABLE}")
        }

        /// Load from the default artifact location (fails in stub builds;
        /// missing artifacts are reported first for a clearer message).
        pub fn load_default() -> Result<Runtime> {
            Runtime::load(&ArtifactStore::discover_default()?)
        }

        pub fn n_days(&self) -> usize {
            0
        }

        pub fn n_features(&self) -> usize {
            0
        }

        pub fn bench_dim(&self) -> usize {
            0
        }

        pub fn exec_linreg(
            &self,
            _x: &[f32],
            _y: &[f32],
            _x_next: &[f32],
        ) -> Result<LinregOutput> {
            bail!("{UNAVAILABLE}")
        }

        pub fn exec_benchmark(&self, _a: &[f32], _b: &[f32]) -> Result<BenchOutput> {
            bail!("{UNAVAILABLE}")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_engine::Runtime;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactStore;

    fn runtime() -> Option<(Runtime, ArtifactStore)> {
        if !Runtime::pjrt_enabled() {
            eprintln!("skipping: built without the `pjrt` feature");
            return None;
        }
        // Missing artifacts => skip; broken artifacts must fail loudly.
        let store = ArtifactStore::discover_default().ok()?;
        let rt =
            Runtime::load(&store).expect("artifacts present but failed to load/compile");
        Some((rt, store))
    }

    #[test]
    fn stub_build_reports_itself() {
        if Runtime::pjrt_enabled() {
            return;
        }
        let err = Runtime::load_default().unwrap_err();
        // Either artifacts are missing (discovery error) or the stub
        // reports the missing feature — both must say what to do.
        let msg = format!("{err:#}");
        assert!(
            msg.contains("make artifacts") || msg.contains("pjrt"),
            "unhelpful error: {msg}"
        );
    }

    #[test]
    fn linreg_matches_python_oracle() {
        let Some((rt, store)) = runtime() else { return };
        let f = store.fixtures().unwrap();
        let out = rt.exec_linreg(&f.x, &f.y, &f.x_next).unwrap();
        assert!(
            (out.prediction - f.oracle_pred).abs() < 1e-2,
            "prediction {} vs oracle {}",
            out.prediction,
            f.oracle_pred
        );
        for (i, (got, want)) in out.theta.iter().zip(&f.oracle_theta).enumerate() {
            assert!(
                (got - want).abs() < 1e-2 * want.abs().max(1.0),
                "theta[{i}]: {got} vs {want}"
            );
        }
    }

    #[test]
    fn benchmark_matches_python_oracle() {
        let Some((rt, store)) = runtime() else { return };
        let f = store.fixtures().unwrap();
        let out = rt.exec_benchmark(&f.bench_a, &f.bench_b).unwrap();
        let rel = (out.checksum - f.oracle_bench_sum).abs()
            / f.oracle_bench_sum.abs().max(1.0);
        assert!(rel < 1e-3, "checksum {} vs {}", out.checksum, f.oracle_bench_sum);
    }

    #[test]
    fn shape_validation_errors() {
        let Some((rt, _)) = runtime() else { return };
        assert!(rt.exec_linreg(&[0.0; 3], &[0.0; 512], &[0.0; 16]).is_err());
        assert!(rt.exec_benchmark(&[0.0; 4], &[0.0; 4]).is_err());
    }

    #[test]
    fn execution_counter_increments() {
        let Some((rt, store)) = runtime() else { return };
        let f = store.fixtures().unwrap();
        let before = rt.executions.get();
        rt.exec_benchmark(&f.bench_a, &f.bench_b).unwrap();
        assert_eq!(rt.executions.get(), before + 1);
    }
}

//! Timing anchors: tie the simulator's virtual durations to *real measured
//! execution* of the identical HLO modules.
//!
//! The paper's durations are on GCF's 0.167-vCPU tier; our host CPU is much
//! faster. We measure the real local wall-clock of the benchmark and
//! analysis executables, then report the scale factor that maps local time
//! onto the paper's regime (Fig. 4 shows ~2.0–2.5 s regression steps). The
//! simulator uses the paper-regime anchors; examples that execute for real
//! report both numbers.

use anyhow::Result;

use super::engine::Runtime;
use crate::stats::descriptive;
use crate::util::prng::Rng;

/// Measured local timings and derived paper-regime anchors.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Median local wall-clock of one benchmark execution, ms.
    pub local_bench_ms: f64,
    /// Median local wall-clock of one analysis execution, ms.
    pub local_analysis_ms: f64,
    /// Anchor: benchmark duration on a nominal paper-tier instance, ms.
    pub paper_bench_ms: f64,
    /// Anchor: analysis duration on a nominal paper-tier instance, ms.
    pub paper_analysis_ms: f64,
    /// Derived local→paper slowdown factor (how much slower 0.167 vCPU is).
    pub tier_scale: f64,
}

/// The paper-regime anchors (from Fig. 4's y-range and the need for the
/// benchmark to hide inside the ~500 ms download, §II-C).
pub const PAPER_ANALYSIS_MS: f64 = 2_300.0;
pub const PAPER_BENCH_MS: f64 = 350.0;

impl Calibration {
    /// Measure `reps` executions of each module and derive anchors.
    pub fn measure(rt: &Runtime, reps: usize) -> Result<Calibration> {
        assert!(reps >= 3, "need a few reps for a stable median");
        let mut rng = Rng::new(0xCA11B);
        let dim = rt.bench_dim();
        let a: Vec<f32> = (0..dim * dim).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..dim * dim).map(|_| rng.normal() as f32).collect();
        let nd = rt.n_days();
        let nf = rt.n_features();
        let x: Vec<f32> = (0..nd * nf).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..nd).map(|_| rng.normal() as f32).collect();
        let xn: Vec<f32> = (0..nf).map(|_| rng.normal() as f32).collect();

        // Warm-up (first execution includes one-time lazy setup).
        rt.exec_benchmark(&a, &b)?;
        rt.exec_linreg(&x, &y, &xn)?;

        let mut bench_ms = Vec::with_capacity(reps);
        let mut analysis_ms = Vec::with_capacity(reps);
        for _ in 0..reps {
            bench_ms.push(rt.exec_benchmark(&a, &b)?.elapsed.as_secs_f64() * 1e3);
            analysis_ms.push(rt.exec_linreg(&x, &y, &xn)?.elapsed.as_secs_f64() * 1e3);
        }
        let local_bench_ms = descriptive::median(&bench_ms);
        let local_analysis_ms = descriptive::median(&analysis_ms);
        Ok(Calibration {
            local_bench_ms,
            local_analysis_ms,
            paper_bench_ms: PAPER_BENCH_MS,
            paper_analysis_ms: PAPER_ANALYSIS_MS,
            tier_scale: PAPER_ANALYSIS_MS / local_analysis_ms.max(1e-6),
        })
    }

    pub fn report(&self) -> String {
        format!(
            "local bench {:.3} ms, local analysis {:.3} ms; \
             paper-tier anchors: bench {:.0} ms, analysis {:.0} ms \
             (tier scale ×{:.0})",
            self.local_bench_ms,
            self.local_analysis_ms,
            self.paper_bench_ms,
            self.paper_analysis_ms,
            self.tier_scale
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactStore;

    #[test]
    fn calibration_produces_positive_anchors() {
        if !Runtime::pjrt_enabled() {
            eprintln!("skipping: built without the `pjrt` feature");
            return;
        }
        let Ok(store) = ArtifactStore::discover_default() else {
            eprintln!("skipping: artifacts not found — run `make artifacts` first");
            return;
        };
        let rt =
            Runtime::load(&store).expect("artifacts present but failed to load/compile");
        let c = Calibration::measure(&rt, 3).unwrap();
        assert!(c.local_bench_ms > 0.0);
        assert!(c.local_analysis_ms > 0.0);
        assert!(c.tier_scale > 1.0, "host should be faster than 0.167 vCPU");
        assert!(c.report().contains("paper-tier"));
    }
}
